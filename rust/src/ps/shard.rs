//! Hash-routed parameter-server shards (see the [`ps`](super) module
//! docs for the architecture).
//!
//! [`spawn`] starts the in-process constellation: N stat-shard threads,
//! one aggregator thread (a [`ParameterServer`] that never sees function
//! deltas), and one merge thread that folds partial snapshot deltas into
//! the viz ingest channel. [`spawn_with`] additionally accepts a list of
//! remote shard *endpoints* (`ps-shard-server` processes), in which case
//! the stat shards live in other processes and every shard connection is
//! a TCP socket instead of a channel.
//!
//! ## Placement
//!
//! Routing is no longer a frozen hash: every constellation owns an
//! epoch-versioned [`Placement`] table (slot → shard, see
//! [`placement`](crate::placement)). Each shard holds its own copy;
//! every sync frame carries the sender's epoch, and a shard that sees a
//! frame from another epoch answers `Rerouted`, making the client
//! refresh its table and resend only the rejected sub-frames. The
//! rebalancer ([`rebalance`](super::rebalance)) watches per-slot merge
//! counters, plans slot moves when one shard runs hot, migrates the
//! affected `RunStats` state shard→shard (extract at the source, install
//! at the destination — pending slots block syncs in between, so a
//! migrated summary is adopted bit-for-bit, never re-merged), and only
//! then commits the new epoch.
//!
//! [`PsClient`] is the one router the on-node AD modules talk to — over
//! in-process channels, over per-shard TCP endpoints, or through a
//! single front-end (the degenerate single-endpoint deployment). The
//! connection kind is invisible above this module. [`PsHandle::join`]
//! tears the constellation down and returns the merged final state
//! ([`PsFinal`]).

use super::rebalance::{RebalanceReport, Rebalancer};
use super::{
    FuncKey, GlobalEvent, ParameterServer, PsReply, PsRequest, StepStat, VizSnapshot,
};
use crate::placement::{Placement, SLOTS};
use crate::stats::{RunStats, StatsTable};
use crate::util::net::Reconnector;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Epoch-0 shard routing: which of `n_shards` owns `(app, fid)` before
/// any rebalance — the [`Placement`] default, kept as a free function for
/// call sites that never see a live table (tests, offline tools). A
/// constellation routes with its *current* placement, not this.
pub fn shard_of(app: u32, fid: u32, n_shards: usize) -> usize {
    Placement::default_shard_of(app, fid, n_shards)
}

/// A sync that keeps being rerouted is waiting on a migration commit;
/// this bounds the wait (attempts sleep ~1 ms when the table has not
/// advanced, so the budget is generous) before degrading like a dead
/// shard connection.
const SYNC_RETRY_MAX: usize = 2_000;

/// How long a shard holds gained slots pending before concluding the
/// migration's Install is never coming (rebalancer crashed or the
/// connection died between phases) and opening them empty — the
/// crashed-shard degradation the protocol promises, instead of bouncing
/// every sync on those slots forever.
const PENDING_TTL: Duration = Duration::from_secs(2);

/// The routing table, shared by reference: readers take the lock only to
/// clone the `Arc` (the 256-slot table itself is cloned only when a
/// migration commits), so the per-sync snapshot is pointer-sized.
pub(crate) type SharedPlacement = Arc<RwLock<Arc<Placement>>>;

/// Message to one stat shard.
pub(crate) enum ShardMsg {
    /// Batched sub-delta for this shard, partitioned under the sender's
    /// placement `epoch`; replies with the merged global stats for
    /// exactly the functions in the sub-delta (plus the shard's view of
    /// the aggregator event version) — or `Rerouted` when the epoch does
    /// not match the shard's table.
    Sync {
        app: u32,
        epoch: u64,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<ShardReply>,
    },
    /// Partial snapshot (function count + load counters) for the merge
    /// stage.
    Snapshot { reply: Sender<VizSnapshot> },
    /// Cumulative per-slot merge counters (the rebalancer's skew signal).
    SlotLoads { reply: Sender<ShardSlotLoads> },
    /// Migration phase 1: adopt `placement` (strictly newer epoch),
    /// mark newly gained slots pending, and return the entries this
    /// shard no longer owns.
    Migrate {
        placement: Placement,
        reply: Sender<Vec<(FuncKey, RunStats)>>,
    },
    /// Migration phase 2: adopt the migrated entries and open the
    /// pending slots for traffic.
    Install {
        entries: Vec<(FuncKey, RunStats)>,
        reply: Sender<()>,
    },
    /// Chaos-plane checkpoint: dump every owned entry, key-sorted,
    /// without disturbing the table (unlike `Migrate`, which moves
    /// entries out). The supervisor snapshots a shard through this each
    /// sync step so a killed replacement can be re-seeded via `Install`.
    Extract {
        reply: Sender<Vec<(FuncKey, RunStats)>>,
    },
    /// Stop and return the owned partition.
    Shutdown,
}

/// A stat shard's reply to a sync sub-frame.
pub(crate) enum ShardReply {
    /// Frame accepted and merged.
    Part(ShardPart),
    /// Frame refused wholesale: the sender's epoch does not match the
    /// shard's table (or a just-gained slot is still awaiting its
    /// migrated state). Nothing was merged; the untouched delta rides
    /// back so an in-process client can resend it without having cloned
    /// it up front (a TCP client keeps its own copy instead — the wire
    /// reply carries only the shard's epoch).
    Rerouted { epoch: u64, delta: Vec<(u32, RunStats)> },
    /// Protocol violation: an entry this shard does not own *at the same
    /// epoch*. The transport drops the connection (trust boundary).
    Refused,
}

/// An accepted sync sub-frame's payload: merged entries plus the
/// piggybacked aggregator event version (see the gating protocol in the
/// module docs).
pub(crate) struct ShardPart {
    pub entries: Vec<(u32, RunStats)>,
    pub event_version: u64,
}

/// One shard's cumulative per-slot merge counters (only touched slots),
/// plus the epoch its table is at — the rebalancer's skew signal *and*
/// its staleness probe (a shard behind the committed epoch missed a
/// Migrate and gets the table re-pushed).
pub(crate) struct ShardSlotLoads {
    pub shard: u32,
    pub epoch: u64,
    pub loads: Vec<(u32, u64)>,
}

/// One pluggable shard connection: an in-process channel to a shard
/// thread, or a *pool* of reconnecting TCP connections to a
/// `ps-shard-server` endpoint (one connection per pool slot; a sync
/// picks `rank % pool`, so concurrent AD workers no longer serialize
/// behind a single write→read window per shard). Control traffic
/// (snapshots, version pushes, migration) uses pool slot 0.
pub(crate) enum ShardConn {
    Local(Sender<ShardMsg>),
    Tcp(Vec<Mutex<Reconnector<super::net::ShardWire>>>),
}

/// Connection to the aggregator/front-end: the in-process request
/// channel, or a reconnecting TCP connection to a `ps-server` front-end.
pub(crate) enum AggConn {
    Local(Sender<PsRequest>),
    Tcp(Mutex<Reconnector<super::net::AggWire>>),
}

/// How a [`PsClient`] reaches the stat shards.
pub(crate) enum Route {
    /// Per-shard connections (channels or TCP endpoints); the client
    /// gates the aggregator event fetch itself.
    Sharded(Arc<Vec<ShardConn>>),
    /// Everything behind one front-end endpoint: grouped sync frames,
    /// server-side routing and gating (the degenerate deployment).
    Frontend { n_shards: usize },
}

/// Per-(app, rank) event-gating state (see the module docs). Reports
/// are counted, not flagged: a sync samples `reports` and, after a
/// successful fetch, acknowledges exactly that many — so a report racing
/// in from another thread between the sample and the acknowledgement
/// still leaves `reports > acked_reports` and forces the next sync to
/// fetch (a boolean here would clobber the racing report's bit).
#[derive(Default)]
pub(crate) struct Gate {
    /// Reports this rank has sent (monotonic).
    reports: u64,
    /// Reports an aggregator event fetch has serialized behind.
    acked_reports: u64,
    /// Highest aggregator event version this rank has observed.
    seen: u64,
}

/// Cloneable router handle used by on-node AD modules — in-process and
/// remote clients are the *same type* over different connections.
///
/// `sync` splits the delta under the client's current [`Placement`],
/// batches one message per touched shard, fans them out (pipelining
/// writes before reads on TCP connections), reassembles the reply
/// client-side, resends any `Rerouted` sub-frame under a refreshed
/// table, and fetches undelivered global events from the aggregator only
/// when the version gate says there may be any.
#[derive(Clone)]
pub struct PsClient {
    pub(crate) route: Route,
    pub(crate) agg: Arc<AggConn>,
    /// This client's view of the routing table. In-process clients share
    /// the constellation's table (commits are visible immediately);
    /// routed TCP clients refresh theirs from the front-end on reroute.
    pub(crate) placement: SharedPlacement,
    pub(crate) sync_count: Arc<AtomicU64>,
    /// Event-fetch messages sent to the aggregator (the gated leg).
    pub(crate) agg_fetches: Arc<AtomicU64>,
    /// Sub-frames bounced with `Rerouted` (stale epoch → refresh+retry).
    pub(crate) reroutes: Arc<AtomicU64>,
    /// Entries dropped by the router after its retry budget / degraded
    /// paths gave up (dead shard, behind-epoch shard, exhausted reroute
    /// loop). The chaos harness (`rust/docs/chaos.md`) sums this into its
    /// bounded-loss ledger — loss is *counted*, never silent.
    pub(crate) sync_lost: Arc<AtomicU64>,
    pub(crate) gates: Arc<Mutex<HashMap<(u32, u32), Gate>>>,
}

impl Clone for Route {
    fn clone(&self) -> Route {
        match self {
            Route::Sharded(c) => Route::Sharded(c.clone()),
            Route::Frontend { n_shards } => Route::Frontend { n_shards: *n_shards },
        }
    }
}

/// Aggregate PS counters readable through the router (local constellation
/// or the front-end's wire stats) — the e2e tests compare these across
/// deployments.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PsStats {
    pub total_anomalies: u64,
    pub total_executions: u64,
    pub ranks: u32,
    pub event_version: u64,
    pub global_events: Vec<GlobalEvent>,
}

impl PsClient {
    /// Number of stat shards this client routes across.
    pub fn shard_count(&self) -> usize {
        match &self.route {
            Route::Sharded(c) => c.len(),
            Route::Frontend { n_shards } => *n_shards,
        }
    }

    /// Event-fetch messages this client has sent to the aggregator. In
    /// the no-events steady state (no reports, no version bumps) this
    /// stays at 0 while `sync` counts climb — the gating win the fig7
    /// endpoint sweep measures.
    pub fn agg_fetch_count(&self) -> u64 {
        self.agg_fetches.load(Ordering::Relaxed)
    }

    /// Routed (non-empty) syncs this client has issued.
    pub fn sync_count_value(&self) -> u64 {
        self.sync_count.load(Ordering::Relaxed)
    }

    /// Sync sub-frames bounced with `Rerouted` (each one refreshed the
    /// table and was resent). Climbs only across a live rebalance.
    pub fn reroute_count(&self) -> u64 {
        self.reroutes.load(Ordering::Relaxed)
    }

    /// Stat entries this router dropped on degraded paths (unreachable
    /// shard, behind-epoch shard, exhausted retry budget). Zero in a
    /// healthy run; the chaos harness asserts observed loss equals the
    /// counter sum.
    pub fn sync_lost_count(&self) -> u64 {
        self.sync_lost.load(Ordering::Relaxed)
    }

    /// Epoch of the routing table this client currently syncs under.
    pub fn placement_epoch(&self) -> u64 {
        self.placement.read().expect("ps placement lock").epoch()
    }

    /// Snapshot of the current routing table (the front-end serves hello
    /// and placement fetches from this). Cheap: clones the `Arc`, not
    /// the table.
    pub(crate) fn placement_snapshot(&self) -> Arc<Placement> {
        self.placement.read().expect("ps placement lock").clone()
    }

    /// Adopt a placement received from the front-end (reroute healing).
    /// The wire is a trust boundary: a table for a different shard count
    /// would send the fan-out out of bounds, so it is refused loudly;
    /// an older-or-equal epoch is a no-op.
    fn adopt_placement(&self, p: Placement) {
        if p.n_shards() != self.shard_count() {
            crate::log_warn!(
                "ps",
                "refusing placement for {} shards (client routes {})",
                p.n_shards(),
                self.shard_count()
            );
            return;
        }
        let mut cur = self.placement.write().expect("ps placement lock");
        if p.epoch() > cur.epoch() {
            *cur = Arc::new(p);
        }
    }

    /// Synchronous stats exchange: send local delta, adopt global reply.
    /// Returns the global snapshot for the touched functions plus any
    /// fresh globally detected events (§V trigger).
    pub fn sync(&self, app: u32, rank: u32, delta: &StatsTable) -> (StatsTable, Vec<GlobalEvent>) {
        if delta.is_empty() {
            return (StatsTable::new(), Vec::new());
        }
        self.sync_entries(app, rank, delta.iter().map(|(f, s)| (f, *s)).collect())
    }

    /// Routed sync from a flat entry list. The client partitions under
    /// its current placement, fans out with the table's epoch attached,
    /// and — when a shard answers `Rerouted` — refreshes the table and
    /// resends only the bounced entries, so every entry merges exactly
    /// once. The TCP front-end calls this for validated grouped frames.
    pub(crate) fn sync_entries(
        &self,
        app: u32,
        rank: u32,
        mut entries: Vec<(u32, RunStats)>,
    ) -> (StatsTable, Vec<GlobalEvent>) {
        if entries.is_empty() {
            return (StatsTable::new(), Vec::new());
        }
        self.sync_count.fetch_add(1, Ordering::Relaxed);
        let conns = match &self.route {
            Route::Sharded(c) => c.clone(),
            Route::Frontend { .. } => return self.sync_frontend(app, rank, entries),
        };
        let key = (app, rank);
        let (reports_now, acked, seen) = {
            let g = self.gates.lock().expect("ps gate lock");
            g.get(&key).map(|x| (x.reports, x.acked_reports, x.seen)).unwrap_or((0, 0, 0))
        };
        let dirty = reports_now > acked;

        // Event-fetch leg, sent *before* collecting shard replies when we
        // already know a fetch must happen (this rank reported since its
        // last aggregator contact), so the two legs overlap — and so the
        // fetch serializes behind the report in the aggregator's queue,
        // preserving exactly-once, next-sync delivery.
        let mut early: Option<Receiver<PsReply>> = None;
        if dirty {
            if let AggConn::Local(tx) = self.agg.as_ref() {
                let (etx, erx) = channel();
                let req = PsRequest::Sync { app, rank, delta: Vec::new(), reply: etx };
                if tx.send(req).is_ok() {
                    self.agg_fetches.fetch_add(1, Ordering::Relaxed);
                    early = Some(erx);
                }
            }
        }

        let mut table = StatsTable::new();
        let mut vmax = 0u64;
        let mut last_epoch = u64::MAX;
        let mut attempts = 0usize;
        while !entries.is_empty() {
            attempts += 1;
            if attempts > SYNC_RETRY_MAX {
                crate::log_warn!(
                    "ps",
                    "sync rerouted {attempts} times without a committed placement; \
                     dropping {} entries",
                    entries.len()
                );
                self.sync_lost.fetch_add(entries.len() as u64, Ordering::Relaxed);
                break;
            }
            let placement = self.placement_snapshot();
            if placement.epoch() == last_epoch {
                // Same table as the attempt that was just bounced: the
                // migration has not committed yet — give it a beat.
                std::thread::sleep(Duration::from_millis(1));
            }
            last_epoch = placement.epoch();
            let epoch = placement.epoch();
            let n = conns.len();
            let mut parts: Vec<Vec<(u32, RunStats)>> = vec![Vec::new(); n];
            for (fid, st) in entries.drain(..) {
                parts[placement.shard_of(app, fid)].push((fid, st));
            }
            // `entries` is drained: it now accumulates bounced sub-frames
            // for the next attempt. `sent[i]` keeps a TCP sub-frame until
            // its reply says it merged (the wire Rerouted reply carries no
            // payload); local shards return the delta inside `Rerouted`,
            // so the channel path moves the Vec instead of cloning it.
            let mut sent: Vec<Option<Vec<(u32, RunStats)>>> = (0..n).map(|_| None).collect();

            // Fan out: local shards get channel sends (their replies
            // arrive on `rrx`); TCP shards get pipelined writes — every
            // request goes out before any reply is read, with each
            // connection's lock held across its write→read window
            // (acquired in shard-index order, so concurrent clients
            // cannot deadlock).
            let (rtx, rrx) = channel();
            let mut expected = 0usize;
            let mut tcp: Vec<(
                std::sync::MutexGuard<'_, Reconnector<super::net::ShardWire>>,
                bool,
                usize,
            )> = Vec::new();
            for (i, part) in parts.into_iter().enumerate() {
                if part.is_empty() {
                    continue;
                }
                match &conns[i] {
                    ShardConn::Local(tx) => {
                        let msg = ShardMsg::Sync { app, epoch, delta: part, reply: rtx.clone() };
                        match tx.send(msg) {
                            Ok(()) => expected += 1,
                            Err(e) => {
                                if let ShardMsg::Sync { delta, .. } = e.0 {
                                    self.sync_lost
                                        .fetch_add(delta.len() as u64, Ordering::Relaxed);
                                }
                                crate::log_warn!("ps", "local shard {i} channel closed");
                            }
                        }
                    }
                    ShardConn::Tcp(pool) => {
                        let mut g = pool[rank as usize % pool.len()]
                            .lock()
                            .expect("ps shard conn lock");
                        let ok = match g.get() {
                            Ok(w) => match w.send_sync(app, epoch, &part) {
                                Ok(()) => {
                                    sent[i] = Some(part);
                                    true
                                }
                                Err(e) => {
                                    crate::log_warn!("ps", "shard sync send failed: {e:#}");
                                    self.sync_lost
                                        .fetch_add(part.len() as u64, Ordering::Relaxed);
                                    g.fail();
                                    false
                                }
                            },
                            Err(e) => {
                                crate::log_warn!("ps", "shard unreachable: {e:#}");
                                self.sync_lost.fetch_add(part.len() as u64, Ordering::Relaxed);
                                false
                            }
                        };
                        tcp.push((g, ok, i));
                    }
                }
            }
            drop(rtx);

            for (mut g, ok, i) in tcp {
                if !ok {
                    continue;
                }
                if g.get().is_err() {
                    // Connection died between the pipelined write and the
                    // read leg: the sub-frame is gone — count it.
                    let n = sent[i].take().map_or(0, |p| p.len());
                    self.sync_lost.fetch_add(n as u64, Ordering::Relaxed);
                    continue;
                }
                if let Ok(w) = g.get() {
                    match w.recv_sync() {
                        Ok(super::net::ShardSyncResp::Ok { entries: got, version }) => {
                            sent[i] = None;
                            for (fid, st) in got {
                                table.replace(fid, st);
                            }
                            vmax = vmax.max(version);
                        }
                        Ok(super::net::ShardSyncResp::Rerouted { epoch: shard_epoch }) => {
                            if shard_epoch < epoch {
                                // The shard is *behind* the table this
                                // frame was built from: it missed a
                                // migration and cannot serve until the
                                // rebalancer re-pushes the table. Degrade
                                // fast like a dead connection instead of
                                // spinning the retry budget.
                                let n = sent[i].take().map_or(0, |p| p.len());
                                self.sync_lost.fetch_add(n as u64, Ordering::Relaxed);
                                crate::log_warn!(
                                    "ps",
                                    "shard {i} is at epoch {shard_epoch}, behind {epoch}; \
                                     dropping its sub-frame"
                                );
                            } else {
                                self.reroutes.fetch_add(1, Ordering::Relaxed);
                                entries.extend(sent[i].take().unwrap_or_default());
                            }
                        }
                        Err(e) => {
                            let n = sent[i].take().map_or(0, |p| p.len());
                            self.sync_lost.fetch_add(n as u64, Ordering::Relaxed);
                            crate::log_warn!("ps", "shard sync reply failed: {e:#}");
                            g.fail();
                        }
                    }
                }
            }
            for _ in 0..expected {
                match rrx.recv() {
                    Ok(ShardReply::Part(part)) => {
                        for (fid, st) in part.entries {
                            table.replace(fid, st);
                        }
                        vmax = vmax.max(part.event_version);
                    }
                    Ok(ShardReply::Rerouted { epoch: shard_epoch, delta }) => {
                        if shard_epoch < epoch {
                            // Behind-the-commit shard (see the TCP arm):
                            // fast-fail its slice rather than retry.
                            self.sync_lost.fetch_add(delta.len() as u64, Ordering::Relaxed);
                            crate::log_warn!(
                                "ps",
                                "local shard at epoch {shard_epoch}, behind {epoch}; \
                                 dropping its sub-frame"
                            );
                        } else {
                            self.reroutes.fetch_add(1, Ordering::Relaxed);
                            entries.extend(delta);
                        }
                    }
                    Ok(ShardReply::Refused) => {
                        // A client partitioning with its own table at its
                        // own epoch cannot misgroup; treat as dropped.
                        crate::log_warn!("ps", "shard refused a locally routed frame");
                    }
                    Err(_) => break,
                }
            }
            if !entries.is_empty() {
                self.refresh_placement();
            }
        }

        // Version-gated event fetch: only when this rank reported since
        // its last aggregator contact, or a shard piggybacked a version
        // newer than anything this rank has seen.
        let fetched: Option<(u64, Vec<GlobalEvent>)> = if let Some(erx) = early {
            erx.recv().ok().map(|r| (r.event_version, r.global_events))
        } else if dirty || vmax > seen {
            self.agg_fetches.fetch_add(1, Ordering::Relaxed);
            self.fetch_events_inner(app, rank)
        } else {
            None
        };
        let (events, did_fetch, fetched_ver) = match fetched {
            Some((ver, evs)) => (evs, true, ver),
            None => (Vec::new(), false, 0),
        };
        if did_fetch {
            // Advance the gate only on a *successful* fetch: if the
            // aggregator was unreachable, recording the piggybacked
            // version now would make every later sync compare equal and
            // silently skip the delivery forever; leaving the gate
            // untouched makes the next sync retry. Acknowledge only the
            // reports sampled above — one racing in since then keeps
            // `reports > acked_reports` and forces the next fetch.
            let mut g = self.gates.lock().expect("ps gate lock");
            let e = g.entry(key).or_default();
            e.acked_reports = e.acked_reports.max(reports_now);
            e.seen = e.seen.max(vmax).max(fetched_ver);
        }
        (table, events)
    }

    /// Pull a fresher routing table after a reroute. In-process clients
    /// share the constellation's table, so there is nothing to fetch —
    /// the commit itself updates it; routed TCP clients ask the
    /// front-end.
    fn refresh_placement(&self) {
        if let AggConn::Tcp(m) = self.agg.as_ref() {
            match m.lock().expect("ps agg conn lock").with(|w| w.fetch_placement()) {
                Ok(p) => self.adopt_placement(p),
                Err(e) => crate::log_warn!("ps", "placement refresh failed: {e:#}"),
            }
        }
    }

    /// Degenerate single-endpoint route: one grouped frame to the
    /// front-end, which routes server-side (and gates the event fetch
    /// with *its* in-process client, so the reply still carries fresh
    /// events exactly once). A `Rerouted` reply carries the committed
    /// table — adopt it and resend the whole frame (nothing merged).
    fn sync_frontend(
        &self,
        app: u32,
        rank: u32,
        entries: Vec<(u32, RunStats)>,
    ) -> (StatsTable, Vec<GlobalEvent>) {
        let AggConn::Tcp(m) = self.agg.as_ref() else {
            return (StatsTable::new(), Vec::new());
        };
        let mut attempts = 0usize;
        loop {
            attempts += 1;
            if attempts > SYNC_RETRY_MAX {
                crate::log_warn!("ps", "front-end sync rerouted {attempts} times; dropping");
                self.sync_lost.fetch_add(entries.len() as u64, Ordering::Relaxed);
                return (StatsTable::new(), Vec::new());
            }
            let placement = self.placement_snapshot();
            let mut parts: Vec<Vec<(u32, RunStats)>> =
                vec![Vec::new(); placement.n_shards()];
            for (fid, st) in &entries {
                parts[placement.shard_of(app, *fid)].push((*fid, *st));
            }
            let res = m
                .lock()
                .expect("ps agg conn lock")
                .with(|w| w.sync_grouped(app, rank, placement.epoch(), &parts));
            match res {
                Ok(super::net::GroupedResp::Ok { entries: got, events }) => {
                    let mut table = StatsTable::new();
                    for (fid, st) in got {
                        table.replace(fid, st);
                    }
                    return (table, events);
                }
                Ok(super::net::GroupedResp::Rerouted(p)) => {
                    self.reroutes.fetch_add(1, Ordering::Relaxed);
                    let before = self.placement_epoch();
                    self.adopt_placement(p);
                    if self.placement_epoch() == before {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }
                Err(e) => {
                    crate::log_warn!("ps", "front-end sync failed (will reconnect): {e:#}");
                    self.sync_lost.fetch_add(entries.len() as u64, Ordering::Relaxed);
                    return (StatsTable::new(), Vec::new());
                }
            }
        }
    }

    /// One event-fetch round-trip to the aggregator (advances this
    /// rank's delivery cursor). Returns the aggregator's event version
    /// plus the events this rank had not yet seen.
    fn fetch_events_inner(&self, app: u32, rank: u32) -> Option<(u64, Vec<GlobalEvent>)> {
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let (etx, erx) = channel();
                tx.send(PsRequest::Sync { app, rank, delta: Vec::new(), reply: etx }).ok()?;
                erx.recv().ok().map(|r| (r.event_version, r.global_events))
            }
            AggConn::Tcp(m) => {
                match m.lock().expect("ps agg conn lock").with(|w| w.fetch_events(app, rank)) {
                    Ok(v) => Some(v),
                    Err(e) => {
                        crate::log_warn!("ps", "event fetch failed (will reconnect): {e:#}");
                        None
                    }
                }
            }
        }
    }

    /// Explicit event fetch for this rank (the TCP front-end serves
    /// `KIND_EVENT_FETCH` through this). Does not touch the client-side
    /// gate — the caller owns its own gating.
    pub fn fetch_events(&self, app: u32, rank: u32) -> (u64, Vec<GlobalEvent>) {
        self.fetch_events_inner(app, rank).unwrap_or((0, Vec::new()))
    }

    /// Fire-and-forget anomaly accounting. Marks this rank's gate dirty:
    /// its next sync *must* round-trip to the aggregator (the report may
    /// complete a step quorum and flag a global event, and next-sync
    /// delivery order requires the fetch to serialize behind it).
    pub fn report(&self, stat: StepStat) {
        {
            let mut g = self.gates.lock().expect("ps gate lock");
            g.entry((stat.app, stat.rank)).or_default().reports += 1;
        }
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let _ = tx.send(PsRequest::Report(stat));
            }
            AggConn::Tcp(m) => {
                if let Err(e) = m.lock().expect("ps agg conn lock").with(|w| w.report(&stat)) {
                    crate::log_warn!("ps", "report failed (will reconnect): {e:#}");
                }
            }
        }
    }

    /// Aggregate PS counters (totals, rank count, event set). `None`
    /// when the aggregator is unreachable.
    pub fn stats(&self) -> Option<PsStats> {
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let (qtx, qrx) = channel();
                tx.send(PsRequest::Query { reply: qtx }).ok()?;
                let snap = qrx.recv().ok()?;
                Some(PsStats {
                    total_anomalies: snap.total_anomalies,
                    total_executions: snap.total_executions,
                    ranks: snap.ranks.len() as u32,
                    event_version: snap.global_events.len() as u64,
                    global_events: snap.global_events,
                })
            }
            AggConn::Tcp(m) => {
                m.lock().expect("ps agg conn lock").with(|w| w.ps_stats()).ok()
            }
        }
    }

    /// Force a viz publish (the merge stage folds in shard partials).
    /// No-op through a TCP front-end: remote clients do not drive the
    /// server's publish cadence.
    pub fn publish(&self) {
        if let AggConn::Local(tx) = self.agg.as_ref() {
            let _ = tx.send(PsRequest::Publish);
        }
    }

    /// Stop the aggregator (it publishes a final snapshot first). The
    /// stat shards stay up until [`PsHandle::join`] so the final merge
    /// can still gather their partials. No-op through a TCP front-end.
    pub fn shutdown(&self) {
        if let AggConn::Local(tx) = self.agg.as_ref() {
            let _ = tx.send(PsRequest::Shutdown);
        }
    }
}

/// The aggregator's joinable form: the classic flat single-thread
/// aggregator, or a hierarchical aggregation tree ([`crate::aggtree`])
/// whose root owns the same state.
enum AggJoin {
    Flat(JoinHandle<ParameterServer>),
    Tree(crate::aggtree::TreeHandle),
}

/// Joinable handle to a spawned constellation.
pub struct PsHandle {
    shard_txs: Vec<Sender<ShardMsg>>,
    conns: Arc<Vec<ShardConn>>,
    agg_join: AggJoin,
    merge_join: JoinHandle<()>,
    shard_joins: Vec<JoinHandle<HashMap<FuncKey, RunStats>>>,
    sync_count: Arc<AtomicU64>,
    version: Arc<AtomicU64>,
    placement: SharedPlacement,
    rebalancer: Arc<Mutex<Rebalancer>>,
    reb_stop: Arc<AtomicBool>,
    reb_join: Option<JoinHandle<()>>,
}

/// Merged final state of a sharded parameter server.
pub struct PsFinal {
    /// Final snapshot (ranks, totals, global events, function count).
    pub snapshot: VizSnapshot,
    /// The reunited global function-statistics view. Covers the shards
    /// this process hosts; remote shard endpoints contribute only their
    /// function *count* (fetched at join time) to
    /// `snapshot.functions_tracked`.
    pub global: HashMap<FuncKey, RunStats>,
    /// All globally detected events, chronological.
    pub global_events: Vec<GlobalEvent>,
    /// Routed (non-empty) syncs served.
    pub sync_count: u64,
}

impl PsFinal {
    /// Global statistics for one function.
    pub fn global_stats(&self, app: u32, fid: u32) -> Option<&RunStats> {
        self.global.get(&(app, fid))
    }

    /// Number of functions tracked globally.
    pub fn global_len(&self) -> usize {
        self.global.len()
    }
}

impl PsHandle {
    /// Serve every *local* stat shard on its own TCP endpoint (ephemeral
    /// ports); returns one server handle per shard, index-aligned. The
    /// addresses feed `PsTcpServer::start_with_topology` so a front-end
    /// can hand clients the shard→addr map.
    pub fn serve_shard_endpoints(&self) -> anyhow::Result<Vec<super::net::PsShardTcpServer>> {
        (0..self.shard_txs.len())
            .map(|i| self.serve_shard_endpoint_at(i, "127.0.0.1:0"))
            .collect()
    }

    /// Serve one local stat shard at `addr` (tests restart a killed
    /// endpoint on its old port with this, keeping the shard state).
    pub fn serve_shard_endpoint_at(
        &self,
        shard: usize,
        addr: &str,
    ) -> anyhow::Result<super::net::PsShardTcpServer> {
        anyhow::ensure!(
            shard < self.shard_txs.len(),
            "shard {shard} out of range ({} local shards)",
            self.shard_txs.len()
        );
        super::net::PsShardTcpServer::start_wrapping(
            addr,
            self.shard_txs[shard].clone(),
            shard as u32,
            self.shard_txs.len() as u32,
            self.version.clone(),
            crate::util::net::ReactorOpts::default(),
        )
    }

    /// Epoch of the committed routing table.
    pub fn placement_epoch(&self) -> u64 {
        self.placement.read().expect("ps placement lock").epoch()
    }

    /// Snapshot of the committed routing table.
    pub fn placement(&self) -> Placement {
        self.placement.read().expect("ps placement lock").as_ref().clone()
    }

    /// Run one skew check now (same logic as the background cadence):
    /// gather per-slot merge loads since the previous check, and if the
    /// per-shard max/mean exceeds the configured ratio, plan moves,
    /// migrate the affected state, and commit a new epoch. `Ok(None)`
    /// when the window is balanced (or too small to judge).
    pub fn rebalance_once(&self) -> anyhow::Result<Option<RebalanceReport>> {
        self.rebalancer.lock().expect("rebalancer lock").run_once()
    }

    /// Explicit slot reassignment: migrate the state of `moves`
    /// (slot → new shard) and commit the successor epoch. Returns the
    /// new epoch. This is the API a placement-aware operator (or test)
    /// uses; the skew-driven path is [`Self::rebalance_once`].
    pub fn migrate_slots(&self, moves: &[(usize, u32)]) -> anyhow::Result<u64> {
        // Hold the rebalancer lock across read → plan → migrate: only
        // migrations commit placements, and they all hold this lock, so
        // the table cannot change between the read and the handshake
        // (migrate_to re-checks, belt and braces).
        let reb = self.rebalancer.lock().expect("rebalancer lock");
        let cur = self.placement.read().expect("ps placement lock").clone();
        let new = cur.with_moves(moves)?;
        let epoch = new.epoch();
        reb.migrate_to(&cur, new)?;
        Ok(epoch)
    }

    /// Current per-shard load counters (one snapshot round-trip per
    /// shard), sorted by shard id.
    pub fn shard_loads(&self) -> Vec<super::ShardLoad> {
        let mut loads = Vec::new();
        let (ptx, prx) = channel();
        let mut expected = 0usize;
        for conn in self.conns.iter() {
            match conn {
                ShardConn::Local(tx) => {
                    if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                        expected += 1;
                    }
                }
                ShardConn::Tcp(pool) => {
                    if let Ok(p) =
                        pool[0].lock().expect("ps shard conn lock").with(|w| w.snapshot())
                    {
                        loads.extend(p.shard_loads.iter().copied());
                    }
                }
            }
        }
        drop(ptx);
        for _ in 0..expected {
            match prx.recv() {
                Ok(p) => loads.extend(p.shard_loads.iter().copied()),
                Err(_) => break,
            }
        }
        loads.sort_by_key(|l| l.shard);
        loads
    }

    /// Cumulative per-slot merge counters, `(shard, slot, merges)` —
    /// the raw skew signal (benches diff two readings for a windowed
    /// view; counters stay with the shard that did the merging, so a
    /// migrated slot restarts from 0 at its new owner).
    pub fn slot_merge_counters(&self) -> Vec<(u32, u32, u64)> {
        super::rebalance::collect_slot_loads(&self.conns)
            .into_iter()
            .flat_map(|s| s.loads.into_iter().map(move |(slot, m)| (s.shard, slot, m)))
            .collect()
    }

    /// Tear down after [`PsClient::shutdown`] and merge the final state.
    ///
    /// Join order matters: the rebalance cadence first (it must not
    /// touch shard connections mid-teardown), then the aggregator (its
    /// final publish is queued to the merge stage), then the merge stage
    /// (which still queries the live shards for partials), then the
    /// shards. Panics if any server thread panicked.
    pub fn join(mut self) -> PsFinal {
        self.reb_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.reb_join.take() {
            let _ = j.join();
        }
        // Join the aggregator in either shape; both end with the merge
        // stage's job channel closed (the flat aggregator by detaching
        // its viz sender, the tree because the root thread owning the
        // sender has exited by the time `TreeHandle::join` returns).
        enum AggFin {
            Flat(ParameterServer),
            Tree(crate::aggtree::TreeFinal),
        }
        let agg_fin = match self.agg_join {
            AggJoin::Flat(j) => {
                let mut agg = j.join().expect("ps aggregator panicked");
                agg.detach_viz();
                AggFin::Flat(agg)
            }
            AggJoin::Tree(tree) => AggFin::Tree(tree.join()),
        };
        self.merge_join.join().expect("ps merge stage panicked");
        // Gather each shard's final partial (function counts + load
        // counters) while the shards are still alive, so the final
        // snapshot carries per-shard loads like every published delta —
        // `/api/ps_stats` serves these after a finished run too.
        let mut shard_loads: Vec<super::ShardLoad> = Vec::new();
        let mut placement_epoch = 0u64;
        let mut remote_functions = 0u64;
        let (ptx, prx) = channel();
        let mut expected = 0usize;
        for conn in self.conns.iter() {
            match conn {
                ShardConn::Local(tx) => {
                    if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                        expected += 1;
                    }
                }
                ShardConn::Tcp(pool) => {
                    if let Ok(p) =
                        pool[0].lock().expect("ps shard conn lock").with(|w| w.snapshot())
                    {
                        remote_functions += p.functions_tracked;
                        shard_loads.extend(p.shard_loads.iter().copied());
                        placement_epoch = placement_epoch.max(p.placement_epoch);
                    }
                }
            }
        }
        drop(ptx);
        for _ in 0..expected {
            match prx.recv() {
                Ok(p) => {
                    shard_loads.extend(p.shard_loads.iter().copied());
                    placement_epoch = placement_epoch.max(p.placement_epoch);
                }
                Err(_) => break,
            }
        }
        shard_loads.sort_by_key(|l| l.shard);
        for tx in &self.shard_txs {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        let mut global: HashMap<FuncKey, RunStats> = HashMap::new();
        for j in self.shard_joins {
            let part = j.join().expect("ps shard panicked");
            global.extend(part);
        }
        let (mut snapshot, global_events) = match agg_fin {
            AggFin::Flat(agg) => (agg.snapshot(), agg.global_events().to_vec()),
            AggFin::Tree(fin) => {
                // The root owns events/cursors/global step stats; the
                // leaves' absolute fold (`rest`) carries the rank plane
                // and per-node load counters. Merged, they are the flat
                // aggregator's final snapshot.
                let events = fin.root.global_events().to_vec();
                let mut s = fin.root.snapshot();
                s.merge(&fin.rest);
                s.delta = false;
                (s, events)
            }
        };
        snapshot.functions_tracked = global.len() as u64 + remote_functions;
        snapshot.shard_loads = shard_loads;
        snapshot.placement_epoch = placement_epoch;
        PsFinal {
            snapshot,
            global,
            global_events,
            sync_count: self.sync_count.load(Ordering::Relaxed),
        }
    }
}

/// Options for [`spawn_with`]: the full topology/cadence knob set.
#[derive(Default)]
pub struct PsOpts {
    /// Local stat-shard threads (ignored when `endpoints` is non-empty;
    /// 0 behaves as 1).
    pub shards: usize,
    /// Remote shard endpoints (`ps-shard-server` addresses), index ==
    /// shard id. Non-empty switches the constellation to routed TCP
    /// shard connections.
    pub endpoints: Vec<String>,
    /// TCP connections per remote shard endpoint (0 behaves as 1).
    /// Syncs pick `rank % pool`, so the driver's AD workers no longer
    /// serialize behind one write→read window per shard.
    pub conn_pool: usize,
    /// Viz ingest channel for merged snapshot deltas.
    pub viz_tx: Option<Sender<VizSnapshot>>,
    /// Snapshot cadence in Report messages (0 behaves as 1).
    pub publish_every: usize,
    /// Wall-clock snapshot cadence in milliseconds (the paper's 1 s);
    /// 0 disables. Runs *alongside* `publish_every`: whichever fires
    /// first publishes, so viz freshness no longer depends on rank count.
    pub publish_interval_ms: u64,
    /// Reports expected per step (the per-step quorum for global-event
    /// detection).
    pub reports_per_step: usize,
    /// Skew-check cadence of the background rebalancer in milliseconds;
    /// 0 (default) disables the cadence — [`PsHandle::rebalance_once`]
    /// still works on demand.
    pub rebalance_interval_ms: u64,
    /// Rebalance trigger: act when windowed per-shard merge load has
    /// max/mean above this. 1.0 is honoured (most aggressive); values
    /// below 1.0 (including the unset default, 0.0) select 1.5.
    pub rebalance_max_ratio: f64,
    /// Minimum windowed merge count before judging skew (tiny windows
    /// are noise); 0 = judge every window.
    pub rebalance_min_merges: u64,
    /// Trigger probes the aggregator evaluates against every newly
    /// flagged global event (`[probe] trigger` in the config). An event
    /// matching any probe's predicate (and passing its sample clause) is
    /// synthesized into a provenance record ([`global_event_record`])
    /// and sent on `trigger_tx` at flag time — it reaches the provDB
    /// service immediately instead of waiting for the next sync-period
    /// context dump.
    pub trigger_probes: Vec<Arc<crate::probe::Probe>>,
    /// Where trigger hits go; `None` disables trigger evaluation.
    pub trigger_tx: Option<Sender<crate::provenance::ProvRecord>>,
    /// Aggregation-tree fanout: ≥ 2 spreads the aggregator into a
    /// hierarchical fold tree ([`crate::aggtree`]) when
    /// `reports_per_step` spans at least two leaves; 0/1 (default)
    /// keeps the flat single-thread aggregator. The tree is pinned
    /// bit-equivalent to flat, so this is purely a scaling knob.
    pub agg_fanout: usize,
    /// Remote `agg-node` process endpoints by leaf index ("" =
    /// in-process leaf); only read when the tree is engaged.
    pub agg_endpoints: Vec<String>,
}

/// Build the event-version fan-out hook shared by the flat aggregator
/// loop and the tree root: evaluate trigger probes over newly flagged
/// global events, mirror the version into the shared atomic, and push
/// it to remote shard endpoints so piggybacked event-fetch gating works
/// across processes.
fn event_fanout(
    trigger_probes: Vec<Arc<crate::probe::Probe>>,
    trigger_tx: Option<Sender<crate::provenance::ProvRecord>>,
    agg_version: Arc<AtomicU64>,
    push_conns: Arc<Vec<ShardConn>>,
) -> impl FnMut(u64, &[GlobalEvent]) + Send + 'static {
    // Per-probe deterministic sample streams + a reused encode buffer
    // for trigger evaluation (the probe VM reads the binary record
    // form).
    let mut trigger_counters = vec![0u64; trigger_probes.len()];
    let mut trigger_buf: Vec<u8> = Vec::new();
    move |v: u64, fresh: &[GlobalEvent]| {
        // Trigger probes run at flag time, before the next sync period
        // can deliver the event to any rank: a matching event's record
        // is on its way to provDB while the context dumps are still
        // pending.
        if let (false, Some(tx)) = (trigger_probes.is_empty(), &trigger_tx) {
            for ev in fresh {
                let rec = global_event_record(ev);
                trigger_buf.clear();
                crate::provenance::codec::encode(&rec, &mut trigger_buf);
                let mut pushed = false;
                for (pi, probe) in trigger_probes.iter().enumerate() {
                    if !probe.matches(&trigger_buf) {
                        continue;
                    }
                    let keep = probe.sample_keep(trigger_counters[pi]);
                    trigger_counters[pi] += 1;
                    if keep && !pushed {
                        // At most one push per event even when several
                        // probes match.
                        let _ = tx.send(rec.clone());
                        pushed = true;
                    }
                }
            }
        }
        agg_version.store(v, Ordering::SeqCst);
        for conn in push_conns.iter() {
            if let ShardConn::Tcp(pool) = conn {
                if let Err(e) =
                    pool[0].lock().expect("ps shard conn lock").with(|w| w.push_version(v))
                {
                    crate::log_warn!("ps", "version push failed: {e:#}");
                }
            }
        }
    }
}

/// Synthesize the provenance record a trigger probe evaluates for one
/// globally detected event. No single execution is behind a global
/// event, so the record is workflow-scoped: `app`/`rank`/`fid` are
/// `u32::MAX`, `func` is `"workflow.global_event"`, the label is the
/// custom `"global_event"`, `score` is the event's σ-distance from the
/// per-step mean, and `msg_bytes` carries the workflow-wide anomaly
/// total (the record layout has no better-fitting numeric field).
pub fn global_event_record(ev: &GlobalEvent) -> crate::provenance::ProvRecord {
    crate::provenance::ProvRecord {
        call_id: ev.step,
        app: u32::MAX,
        rank: u32::MAX,
        thread: 0,
        fid: u32::MAX,
        func: "workflow.global_event".to_string(),
        step: ev.step,
        entry_us: 0,
        exit_us: 0,
        inclusive_us: 0,
        exclusive_us: 0,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: ev.total_anomalies,
        label: "global_event".to_string(),
        score: ev.score,
    }
}

/// Spawn a sharded parameter server with in-process shards — see
/// [`spawn_with`] for the full option set (remote shard endpoints,
/// wall-clock publish cadence, rebalancing).
///
/// * `n_shards` — stat-shard threads (1 reproduces single-server
///   behaviour exactly);
/// * `viz_tx` — viz ingest channel for merged snapshots;
/// * `publish_every` — snapshot cadence in Report messages;
/// * `reports_per_step` — number of reporting ranks (the per-step quorum
///   for global-event detection).
pub fn spawn(
    n_shards: usize,
    viz_tx: Option<Sender<VizSnapshot>>,
    publish_every: usize,
    reports_per_step: usize,
) -> (PsClient, PsHandle) {
    spawn_with(PsOpts {
        shards: n_shards,
        viz_tx,
        publish_every,
        reports_per_step,
        ..PsOpts::default()
    })
    .expect("spawning local parameter server cannot fail")
}

/// Spawn a parameter-server constellation per `opts`.
///
/// With `endpoints` empty this is the in-process layout ([`spawn`]).
/// With endpoints, each stat shard is a `ps-shard-server` process
/// reached over TCP: the aggregator, merge stage, and rank/step timeline
/// stay here (the front-end), shard connections are dialed eagerly
/// (fail fast on a bad address) and reconnect with backoff afterwards,
/// and the aggregator pushes event-version bumps to every shard endpoint
/// so piggybacked gating works across processes.
pub fn spawn_with(opts: PsOpts) -> anyhow::Result<(PsClient, PsHandle)> {
    let version = Arc::new(AtomicU64::new(0));
    let mut conns: Vec<ShardConn> = Vec::new();
    let mut shard_txs: Vec<Sender<ShardMsg>> = Vec::new();
    let mut shard_joins = Vec::new();
    let n_shards = if opts.endpoints.is_empty() {
        opts.shards.max(1)
    } else {
        opts.endpoints.len()
    };
    anyhow::ensure!(
        n_shards <= SLOTS,
        "at most {SLOTS} shards supported ({n_shards} requested)"
    );
    if opts.endpoints.is_empty() {
        for i in 0..n_shards {
            let (tx, rx): (Sender<ShardMsg>, Receiver<ShardMsg>) = channel();
            let ver = version.clone();
            let join = std::thread::Builder::new()
                .name(format!("chimbuko-ps-shard-{i}"))
                .spawn(move || run_shard(rx, i as u32, n_shards, ver))
                .expect("spawning ps shard");
            conns.push(ShardConn::Local(tx.clone()));
            shard_txs.push(tx);
            shard_joins.push(join);
        }
    } else {
        let pool_size = opts.conn_pool.max(1);
        for (i, ep) in opts.endpoints.iter().enumerate() {
            let (id, total) = (i as u32, n_shards as u32);
            // First pool slot dials eagerly (fail fast on a bad
            // address); the rest dial lazily on first use.
            let wire = super::net::ShardWire::connect(ep, id, total)?;
            let mut pool = vec![Mutex::new(Reconnector::seeded(
                ep,
                move |a: &str| super::net::ShardWire::connect(a, id, total),
                wire,
            ))];
            for _ in 1..pool_size {
                pool.push(Mutex::new(Reconnector::new(ep, move |a: &str| {
                    super::net::ShardWire::connect(a, id, total)
                })));
            }
            conns.push(ShardConn::Tcp(pool));
        }
    }
    let conns = Arc::new(conns);
    let placement: SharedPlacement = Arc::new(RwLock::new(Arc::new(Placement::new(n_shards))));

    // Aggregator: a ParameterServer whose viz sender feeds the merge
    // stage instead of the viz channel directly. It also owns the
    // event-version mirror: after every handled request the version is
    // stored for local shards (shared atomic) and pushed to remote shard
    // endpoints when it changed. With `agg_fanout` ≥ 2 (and enough ranks
    // for two levels) the single thread is replaced by a hierarchical
    // aggregation tree speaking the same request channel; the root runs
    // the same fan-out hook, so gating and triggers are shape-blind.
    let (job_tx, job_rx) = channel::<VizSnapshot>();
    let publish_every = opts.publish_every;
    let reports_per_step = opts.reports_per_step;
    let interval_ms = opts.publish_interval_ms;
    let fanout_hook =
        event_fanout(opts.trigger_probes, opts.trigger_tx, version.clone(), conns.clone());
    let use_tree = opts.agg_fanout >= 2
        && crate::aggtree::TreeSpec::plan(opts.agg_fanout, reports_per_step.max(1)).depth() >= 2;
    let (agg_tx, agg_join) = if use_tree {
        let tree = crate::aggtree::spawn_tree(
            crate::aggtree::TreeOpts {
                fanout: opts.agg_fanout,
                ranks: reports_per_step.max(1),
                publish_every,
                publish_interval_ms: interval_ms,
                endpoints: opts.agg_endpoints.clone(),
            },
            job_tx,
            Box::new(fanout_hook),
        )?;
        let tx = tree.request_sender();
        (tx, AggJoin::Tree(tree))
    } else {
        let (agg_tx, agg_rx): (Sender<PsRequest>, Receiver<PsRequest>) = channel();
        let mut fanout_hook = fanout_hook;
        let join = std::thread::Builder::new()
            .name("chimbuko-ps-agg".into())
            .spawn(move || {
                let mut ps = ParameterServer::new(Some(job_tx), publish_every, reports_per_step);
                let mut running = true;
                let mut last_interval_pub = Instant::now();
                let mut last_ver = 0u64;
                while running {
                    let req = if interval_ms == 0 {
                        match agg_rx.recv() {
                            Ok(r) => Some(r),
                            Err(_) => break,
                        }
                    } else {
                        let budget = Duration::from_millis(interval_ms)
                            .saturating_sub(last_interval_pub.elapsed());
                        match agg_rx.recv_timeout(budget.max(Duration::from_millis(1))) {
                            Ok(r) => Some(r),
                            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    };
                    match req {
                        Some(r) => {
                            if !ps.handle(r) {
                                running = false;
                            }
                            // Wall-clock cadence must also fire under
                            // sustained traffic (recv_timeout never times
                            // out while messages keep arriving), so check
                            // the interval after every handled message too.
                            if interval_ms > 0
                                && last_interval_pub.elapsed()
                                    >= Duration::from_millis(interval_ms)
                            {
                                if ps.pending_publish() {
                                    ps.publish();
                                }
                                last_interval_pub = Instant::now();
                            }
                        }
                        None => {
                            // Idle tick: publish only when something new
                            // arrived since the last snapshot.
                            if ps.pending_publish() {
                                ps.publish();
                            }
                            last_interval_pub = Instant::now();
                        }
                    }
                    let v = ps.event_version();
                    if v != last_ver {
                        fanout_hook(v, &ps.global_events()[last_ver as usize..]);
                        last_ver = v;
                    }
                }
                ps
            })
            .expect("spawning ps aggregator");
        (agg_tx, AggJoin::Flat(join))
    };

    // Merge stage: fold one partial per stat shard onto each aggregator
    // snapshot delta, then forward downstream. Commutative merges make
    // the arrival order irrelevant — no barrier anywhere.
    let merge_conns = conns.clone();
    let viz_tx = opts.viz_tx;
    let merge_join = std::thread::Builder::new()
        .name("chimbuko-ps-merge".into())
        .spawn(move || {
            while let Ok(mut partial) = job_rx.recv() {
                let (ptx, prx) = channel();
                let mut expected = 0usize;
                for conn in merge_conns.iter() {
                    match conn {
                        ShardConn::Local(tx) => {
                            if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                                expected += 1;
                            }
                        }
                        ShardConn::Tcp(pool) => {
                            match pool[0]
                                .lock()
                                .expect("ps shard conn lock")
                                .with(|w| w.snapshot())
                            {
                                Ok(p) => {
                                    let _ = ptx.send(p);
                                    expected += 1;
                                }
                                Err(e) => {
                                    crate::log_warn!("ps", "shard snapshot failed: {e:#}");
                                }
                            }
                        }
                    }
                }
                drop(ptx);
                for _ in 0..expected {
                    match prx.recv() {
                        Ok(p) => partial.merge(&p),
                        Err(_) => break,
                    }
                }
                if let Some(tx) = &viz_tx {
                    let _ = tx.send(partial);
                }
            }
        })
        .expect("spawning ps merge stage");

    // The rebalancer: shared between the on-demand API (PsHandle) and
    // the optional background cadence thread.
    let rebalancer = Arc::new(Mutex::new(Rebalancer::new(
        conns.clone(),
        placement.clone(),
        opts.rebalance_max_ratio,
        opts.rebalance_min_merges,
    )));
    let reb_stop = Arc::new(AtomicBool::new(false));
    let reb_join = if opts.rebalance_interval_ms > 0 {
        let reb = rebalancer.clone();
        let stop = reb_stop.clone();
        let interval = opts.rebalance_interval_ms;
        Some(
            std::thread::Builder::new()
                .name("chimbuko-ps-rebalance".into())
                .spawn(move || {
                    let tick = Duration::from_millis(interval.clamp(1, 25));
                    let mut waited_ms = 0u64;
                    loop {
                        std::thread::sleep(tick);
                        if stop.load(Ordering::Relaxed) {
                            break;
                        }
                        waited_ms += tick.as_millis() as u64;
                        if waited_ms < interval {
                            continue;
                        }
                        waited_ms = 0;
                        match reb.lock().expect("rebalancer lock").run_once() {
                            Ok(Some(r)) => crate::log_info!(
                                "ps",
                                "rebalanced to epoch {} ({} slot moves, max/mean {:.2} → {:.2} planned)",
                                r.epoch,
                                r.moves,
                                r.ratio_before,
                                r.ratio_planned
                            ),
                            Ok(None) => {}
                            Err(e) => crate::log_warn!("ps", "rebalance failed: {e:#}"),
                        }
                    }
                })
                .expect("spawning ps rebalancer"),
        )
    } else {
        None
    };

    let sync_count = Arc::new(AtomicU64::new(0));
    let client = PsClient {
        route: Route::Sharded(conns.clone()),
        agg: Arc::new(AggConn::Local(agg_tx)),
        placement: placement.clone(),
        sync_count: sync_count.clone(),
        agg_fetches: Arc::new(AtomicU64::new(0)),
        reroutes: Arc::new(AtomicU64::new(0)),
        sync_lost: Arc::new(AtomicU64::new(0)),
        gates: Arc::new(Mutex::new(HashMap::new())),
    };
    let handle = PsHandle {
        shard_txs,
        conns,
        agg_join,
        merge_join,
        shard_joins,
        sync_count,
        version,
        placement,
        rebalancer,
        reb_stop,
        reb_join,
    };
    Ok((client, handle))
}

/// One stat shard's loop: own the current placement's partition of the
/// global function statistics, count its load per slot, validate every
/// frame against its own epoch-versioned table, and piggyback the
/// aggregator event version (shared atomic locally; updated by version
/// pushes in a standalone `ps-shard-server`).
pub(crate) fn run_shard(
    rx: Receiver<ShardMsg>,
    shard_id: u32,
    n_shards: usize,
    version: Arc<AtomicU64>,
) -> HashMap<FuncKey, RunStats> {
    let mut table: HashMap<FuncKey, RunStats> = HashMap::new();
    let mut placement = Placement::new(n_shards);
    // Slots gained by an in-flight migration: their state has not been
    // installed yet, so syncs touching them bounce with `Rerouted` (a
    // merge now would reorder against the migrated summary and break
    // bit-equivalence with the reference). If the Install never arrives
    // ([`PENDING_TTL`] — the rebalancer died between phases), the slots
    // open empty: the migrated slice is lost like any crashed shard's,
    // but traffic stops bouncing.
    let mut pending = vec![false; SLOTS];
    let mut pending_since: Option<Instant> = None;
    let mut syncs = 0u64;
    let mut merges = 0u64;
    let mut slot_merges = vec![0u64; SLOTS];
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Sync { app, epoch, delta, reply } => {
                // Validate the whole frame before merging any of it:
                // accept/reject must be atomic, or a client retry after
                // `Rerouted` would double-merge the accepted prefix.
                enum Verdict {
                    Accept(Vec<usize>),
                    Reroute,
                    Refuse,
                }
                let verdict = 'frame: {
                    if epoch != placement.epoch() {
                        break 'frame Verdict::Reroute;
                    }
                    let mut slots = Vec::with_capacity(delta.len());
                    for (fid, _) in &delta {
                        let slot = Placement::slot_of(app, *fid);
                        if placement.shard_of_slot(slot) != shard_id as usize {
                            break 'frame Verdict::Refuse;
                        }
                        if pending[slot] {
                            if pending_since.is_some_and(|t| t.elapsed() < PENDING_TTL) {
                                break 'frame Verdict::Reroute;
                            }
                            // Install never arrived: open the slots empty.
                            pending.fill(false);
                            pending_since = None;
                        }
                        slots.push(slot);
                    }
                    Verdict::Accept(slots)
                };
                let resp = match verdict {
                    Verdict::Reroute => ShardReply::Rerouted {
                        epoch: placement.epoch(),
                        delta,
                    },
                    Verdict::Refuse => ShardReply::Refused,
                    Verdict::Accept(slots) => {
                        syncs += 1;
                        let mut out = Vec::with_capacity(delta.len());
                        for ((fid, st), slot) in delta.iter().zip(&slots) {
                            let g = table.entry((app, *fid)).or_default();
                            g.merge(st);
                            merges += 1;
                            slot_merges[*slot] += 1;
                            out.push((*fid, *g));
                        }
                        ShardReply::Part(ShardPart {
                            entries: out,
                            event_version: version.load(Ordering::SeqCst),
                        })
                    }
                };
                let _ = reply.send(resp);
            }
            ShardMsg::Snapshot { reply } => {
                let _ = reply.send(VizSnapshot {
                    functions_tracked: table.len() as u64,
                    placement_epoch: placement.epoch(),
                    shard_loads: vec![super::ShardLoad {
                        shard: shard_id,
                        syncs,
                        merges,
                        functions: table.len() as u64,
                        slots: placement.slots_of_shard(shard_id).len() as u32,
                        // In-process shard: no transport between client
                        // and shard, so nothing is ever shed or queued.
                        shed: 0,
                        queue_depth: 0,
                    }],
                    ..VizSnapshot::default()
                });
            }
            ShardMsg::SlotLoads { reply } => {
                let loads = slot_merges
                    .iter()
                    .enumerate()
                    .filter(|(_, &m)| m > 0)
                    .map(|(s, &m)| (s as u32, m))
                    .collect();
                let _ = reply.send(ShardSlotLoads {
                    shard: shard_id,
                    epoch: placement.epoch(),
                    loads,
                });
            }
            ShardMsg::Migrate { placement: new, reply } => {
                let mut out: Vec<(FuncKey, RunStats)> = Vec::new();
                if new.epoch() > placement.epoch() {
                    let gained = placement.gains(&new, shard_id);
                    if !gained.is_empty() {
                        pending_since = Some(Instant::now());
                    }
                    for s in gained {
                        pending[s] = true;
                    }
                    table.retain(|&(app, fid), st| {
                        if new.shard_of(app, fid) != shard_id as usize {
                            out.push(((app, fid), *st));
                            false
                        } else {
                            true
                        }
                    });
                    placement = new;
                }
                let _ = reply.send(out);
            }
            ShardMsg::Install { entries, reply } => {
                for ((app, fid), st) in entries {
                    // Pending slots blocked syncs, so this is a pure
                    // move: merging into an absent entry adopts the
                    // migrated moments bit-for-bit.
                    table.entry((app, fid)).or_default().merge(&st);
                }
                pending.fill(false);
                pending_since = None;
                let _ = reply.send(());
            }
            ShardMsg::Extract { reply } => {
                // Key-sorted so two checkpoints of identical state are
                // byte-identical regardless of hash iteration order.
                let mut out: Vec<(FuncKey, RunStats)> =
                    table.iter().map(|(&k, &v)| (k, v)).collect();
                out.sort_unstable_by_key(|&(k, _)| k);
                let _ = reply.send(out);
            }
            ShardMsg::Shutdown => break,
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 7, 16] {
            for app in 0..3u32 {
                for fid in 0..300u32 {
                    let s = shard_of(app, fid, n);
                    assert!(s < n);
                    assert_eq!(s, shard_of(app, fid, n), "must be deterministic");
                    // The free function is the epoch-0 placement.
                    assert_eq!(s, Placement::new(n).shard_of(app, fid));
                }
            }
        }
        // One shard owns everything.
        assert_eq!(shard_of(9, 12345, 1), 0);
    }

    #[test]
    fn shard_of_spreads_keys() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for fid in 0..256u32 {
            counts[shard_of(0, fid, n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 256 / n / 3, "shard {i} starved: {c} of 256 keys");
        }
    }

    #[test]
    fn routed_sync_reassembles_full_reply() {
        let (client, handle) = spawn(4, None, usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..64u32 {
            delta.push(fid, fid as f64 + 1.0);
            delta.push(fid, fid as f64 + 3.0);
        }
        let (global, events) = client.sync(0, 0, &delta);
        assert!(events.is_empty());
        assert_eq!(global.len(), 64, "every touched function must come back");
        for fid in 0..64u32 {
            let st = global.get(fid).unwrap();
            assert_eq!(st.count(), 2);
            assert!((st.mean() - (fid as f64 + 2.0)).abs() < 1e-12);
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 64);
        assert_eq!(fin.snapshot.functions_tracked, 64);
        assert_eq!(fin.sync_count, 1);
    }

    #[test]
    fn merged_snapshots_reach_viz_channel() {
        let (vtx, vrx) = std::sync::mpsc::channel();
        let (client, handle) = spawn(3, Some(vtx), usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..24u32 {
            delta.push(fid, 10.0);
        }
        client.sync(0, 0, &delta);
        client.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 50,
            n_anomalies: 2,
            ts_range: (0, 9),
        });
        client.publish();
        // The published snapshot delta folds the aggregator partial
        // (report totals, changed ranks) with the stat-shard partials
        // (function counts + load counters).
        let snap = vrx.recv().unwrap();
        assert!(snap.delta, "published snapshots are deltas");
        assert_eq!(snap.total_anomalies, 2);
        assert_eq!(snap.total_executions, 50);
        assert_eq!(snap.functions_tracked, 24);
        assert_eq!(snap.ranks.len(), 1);
        assert_eq!(snap.shard_loads.len(), 3, "one load entry per shard");
        let total_merges: u64 = snap.shard_loads.iter().map(|l| l.merges).sum();
        assert_eq!(total_merges, 24);
        let total_syncs: u64 = snap.shard_loads.iter().map(|l| l.syncs).sum();
        assert_eq!(total_syncs, 3, "the routed sync touched every shard once");
        let total_slots: u32 = snap.shard_loads.iter().map(|l| l.slots).sum();
        assert_eq!(total_slots as usize, SLOTS, "shards partition the slot space");
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.snapshot.total_anomalies, 2);
        // The final snapshot carries the load counters too (this is what
        // /api/ps_stats serves after a finished run).
        assert_eq!(fin.snapshot.shard_loads.len(), 3);
        assert_eq!(fin.snapshot.placement_epoch, 0, "no rebalance ran");
        // Final shutdown publish also reached the channel; it is a delta
        // with no new ranks (nothing changed since the explicit publish).
        let last = vrx.recv().unwrap();
        assert_eq!(last.total_anomalies, 2);
        assert!(last.ranks.is_empty(), "unchanged ranks stay out of deltas");
        assert!(vrx.recv().is_err(), "viz channel must close after join");
    }

    #[test]
    fn n1_matches_reference_inline() {
        // The same op sequence through a 1-shard constellation and the
        // single-threaded reference server must agree bit-for-bit.
        let (client, handle) = spawn(1, None, usize::MAX >> 1, 2);
        let mut reference = ParameterServer::new(None, usize::MAX >> 1, 2);
        for step in 0..6u64 {
            for rank in 0..2u32 {
                let stat = StepStat {
                    app: 0,
                    rank,
                    step,
                    n_executions: 40,
                    n_anomalies: (step % 2) * (rank as u64),
                    ts_range: (step, step + 1),
                };
                client.report(stat.clone());
                reference.handle(PsRequest::Report(stat));
                let mut delta = StatsTable::new();
                delta.push(rank, 100.0 + step as f64);
                delta.push(7, 5.0 * (step + 1) as f64);
                let (got, _) = client.sync(0, rank, &delta);
                let (rtx, rrx) = channel();
                reference.handle(PsRequest::Sync {
                    app: 0,
                    rank,
                    delta: delta.iter().map(|(f, s)| (f, *s)).collect(),
                    reply: rtx,
                });
                let want = rrx.recv().unwrap();
                for (fid, st) in want.global {
                    assert_eq!(got.get(fid), Some(&st), "fid {fid} diverged at step {step}");
                }
            }
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), reference.global_len());
        for (key, st) in reference.global_iter() {
            assert_eq!(fin.global.get(&key), Some(st));
        }
        assert_eq!(fin.snapshot.total_anomalies, reference.snapshot().total_anomalies);
        assert_eq!(fin.snapshot.total_executions, reference.snapshot().total_executions);
    }

    #[test]
    fn event_fetch_is_gated_without_reports() {
        // Sync-only load: no reports, no events — the gated client never
        // round-trips to the aggregator (the steady state the endpoint
        // sweep measures).
        let (client, handle) = spawn(2, None, usize::MAX >> 1, 1);
        for rank in 0..4u32 {
            let mut delta = StatsTable::new();
            delta.push(rank, 1.0);
            delta.push(rank + 100, 2.0);
            client.sync(0, rank, &delta);
        }
        assert_eq!(client.agg_fetch_count(), 0, "no reports → no event fetches");
        // A report makes the next sync fetch (dirty gate), exactly once.
        client.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 1,
            n_anomalies: 0,
            ts_range: (0, 1),
        });
        let mut delta = StatsTable::new();
        delta.push(1, 1.0);
        client.sync(0, 0, &delta);
        assert_eq!(client.agg_fetch_count(), 1, "dirty rank must fetch once");
        client.sync(0, 0, &delta);
        assert_eq!(client.agg_fetch_count(), 1, "clean rank must not fetch again");
        client.shutdown();
        handle.join();
    }

    #[test]
    fn wall_clock_publish_cadence() {
        // publish_every is effectively infinite; the 20 ms wall-clock
        // cadence must still flush a snapshot after a report arrives.
        let (vtx, vrx) = std::sync::mpsc::channel();
        let (client, handle) = spawn_with(PsOpts {
            shards: 1,
            viz_tx: Some(vtx),
            publish_every: usize::MAX >> 1,
            publish_interval_ms: 20,
            reports_per_step: 1,
            ..PsOpts::default()
        })
        .unwrap();
        client.report(StepStat {
            app: 0,
            rank: 3,
            step: 0,
            n_executions: 10,
            n_anomalies: 1,
            ts_range: (0, 1),
        });
        let snap = vrx
            .recv_timeout(Duration::from_secs(5))
            .expect("interval publish must fire without an explicit Publish");
        assert!(snap.delta);
        assert_eq!(snap.total_anomalies, 1);
        assert_eq!(snap.ranks.len(), 1);
        client.shutdown();
        handle.join();
    }

    #[test]
    fn trigger_probe_fires_on_global_event() {
        // One reporting rank; 10 quiet steps build the per-step history,
        // then a burst flags a global event — the matching trigger probe
        // must synthesize a record onto the channel at flag time (no sync
        // or publish needed).
        let probe = crate::probe::Probe::compile(
            "probe trig: fn:*.*:exit / func == \"workflow.global_event\" && score > 3.0 /",
        )
        .unwrap();
        let (ttx, trx) = std::sync::mpsc::channel();
        let (client, handle) = spawn_with(PsOpts {
            shards: 1,
            publish_every: usize::MAX >> 1,
            reports_per_step: 1,
            trigger_probes: vec![Arc::new(probe)],
            trigger_tx: Some(ttx),
            ..PsOpts::default()
        })
        .unwrap();
        let report = |step: u64, anoms: u64| {
            client.report(StepStat {
                app: 0,
                rank: 0,
                step,
                n_executions: 100,
                n_anomalies: anoms,
                ts_range: (step, step + 1),
            });
        };
        for step in 0..10 {
            report(step, u64::from(step % 3 == 0));
        }
        report(10, 25);
        let rec = trx
            .recv_timeout(Duration::from_secs(5))
            .expect("trigger probe must push the global-event record");
        assert_eq!(rec.step, 10);
        assert_eq!(rec.label, "global_event");
        assert_eq!(rec.func, "workflow.global_event");
        assert_eq!(rec.msg_bytes, 25);
        assert_eq!((rec.app, rec.rank, rec.fid), (u32::MAX, u32::MAX, u32::MAX));
        assert!(rec.score > 3.0, "score {}", rec.score);
        assert!(rec.is_anomaly(), "custom label must read as anomalous");
        // Quiet steps never triggered: exactly one record on the channel.
        assert!(trx.try_recv().is_err());
        client.shutdown();
        handle.join();
    }

    #[test]
    fn query_stats_through_router() {
        let (client, handle) = spawn(2, None, usize::MAX >> 1, 1);
        client.report(StepStat {
            app: 0,
            rank: 1,
            step: 0,
            n_executions: 30,
            n_anomalies: 4,
            ts_range: (0, 1),
        });
        let stats = client.stats().expect("local stats");
        assert_eq!(stats.total_anomalies, 4);
        assert_eq!(stats.total_executions, 30);
        assert_eq!(stats.ranks, 1);
        assert_eq!(stats.event_version, 0);
        client.shutdown();
        handle.join();
    }

    #[test]
    fn migrate_slots_moves_state_and_bumps_epoch() {
        let (client, handle) = spawn(4, None, usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..32u32 {
            delta.push(fid, fid as f64 + 1.0);
        }
        client.sync(0, 0, &delta);
        assert_eq!(client.placement_epoch(), 0);

        // Move fid 5's slot to a different shard; state must follow.
        let slot = Placement::slot_of(0, 5);
        let from = handle.placement().shard_of_slot(slot) as u32;
        let to = (from + 1) % 4;
        let epoch = handle.migrate_slots(&[(slot, to)]).unwrap();
        assert_eq!(epoch, 1);
        assert_eq!(handle.placement_epoch(), 1);
        assert_eq!(client.placement_epoch(), 1, "in-proc client shares the table");

        // Post-migration syncs still see the full accumulated history.
        let (global, _) = client.sync(0, 0, &delta);
        for fid in 0..32u32 {
            assert_eq!(global.get(fid).unwrap().count(), 2, "fid {fid} lost history");
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 32);
        assert_eq!(fin.snapshot.placement_epoch, 1);
        for fid in 0..32u32 {
            assert_eq!(fin.global_stats(0, fid).unwrap().count(), 2);
        }
    }

    #[test]
    fn rebalance_once_fixes_hot_slot_skew() {
        let (client, handle) = spawn(4, None, usize::MAX >> 1, 1);
        // Hot function in every delta (~1/3 of merges) + a uniform tail.
        let hot = 3u32;
        for i in 0..600u32 {
            let mut delta = StatsTable::new();
            delta.push(hot, 10.0 + i as f64);
            delta.push(8 + (i % 200), 1.0);
            delta.push(8 + ((i * 7 + 3) % 200), 1.0);
            client.sync(0, 0, &delta);
        }
        let before: Vec<u64> = handle.shard_loads().iter().map(|l| l.merges).collect();
        assert!(
            crate::placement::load_ratio(&before) > 1.5,
            "setup must be skewed (loads {before:?})"
        );
        let report = handle.rebalance_once().unwrap().expect("skew must trigger");
        assert!(report.moves > 0);
        assert_eq!(report.epoch, 1);
        assert!(report.ratio_planned < report.ratio_before);

        // Windowed load after the rebalance: diff the cumulative per-slot
        // counters across a second identical traffic phase.
        let snap1 = handle.slot_merge_counters();
        for i in 0..600u32 {
            let mut delta = StatsTable::new();
            delta.push(hot, 10.0 + i as f64);
            delta.push(8 + (i % 200), 1.0);
            delta.push(8 + ((i * 7 + 3) % 200), 1.0);
            client.sync(0, 0, &delta);
        }
        let snap2 = handle.slot_merge_counters();
        let mut shard_window = vec![0u64; 4];
        let prev: HashMap<(u32, u32), u64> =
            snap1.into_iter().map(|(s, slot, m)| ((s, slot), m)).collect();
        for (shard, slot, m) in snap2 {
            shard_window[shard as usize] += m - prev.get(&(shard, slot)).copied().unwrap_or(0);
        }
        let after = crate::placement::load_ratio(&shard_window);
        assert!(
            after < 1.5,
            "rebalanced max/mean {after:.2} must be < 1.5 (window {shard_window:?})"
        );
        client.shutdown();
        handle.join();
    }
}
