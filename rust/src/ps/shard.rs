//! Hash-routed parameter-server shards (see the [`ps`](super) module
//! docs for the architecture).
//!
//! [`spawn`] starts the constellation: N stat-shard threads, one
//! aggregator thread (a [`ParameterServer`] that never sees function
//! deltas), and one merge thread that folds partial snapshots into the
//! viz ingest channel. [`PsClient`] is the hash router the on-node AD
//! modules talk to; [`PsHandle::join`] tears the constellation down and
//! returns the merged final state ([`PsFinal`]).

use super::{
    FuncKey, GlobalEvent, ParameterServer, PsReply, PsRequest, StepStat, VizSnapshot,
};
use crate::stats::{RunStats, StatsTable};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

/// Stable shard routing: which of `n_shards` owns `(app, fid)`.
///
/// One [`splitmix64`](crate::util::rng::splitmix64) step over the packed
/// key — cheap, well-mixed, and identical on both sides of the wire
/// protocol (the TCP client groups deltas with this same function after
/// the hello handshake). The provDB's
/// [`prov_shard_of`](crate::provdb::prov_shard_of) shares the mixer.
pub fn shard_of(app: u32, fid: u32, n_shards: usize) -> usize {
    let mut key = ((app as u64) << 32) | fid as u64;
    (crate::util::rng::splitmix64(&mut key) % n_shards.max(1) as u64) as usize
}

/// Message to one stat shard.
enum ShardMsg {
    /// Batched sub-delta for this shard; replies with the merged global
    /// stats for exactly the functions in the sub-delta.
    Sync {
        app: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<Vec<(u32, RunStats)>>,
    },
    /// Partial snapshot for the merge stage.
    Snapshot { reply: Sender<VizSnapshot> },
    /// Stop and return the owned partition.
    Shutdown,
}

/// Cloneable router handle used by on-node AD modules.
///
/// `sync` splits the delta by [`shard_of`], batches one message per
/// touched shard, fetches undelivered global events from the aggregator,
/// and reassembles the reply client-side.
#[derive(Clone)]
pub struct PsClient {
    /// One sender per stat shard (cloned per client, the mpsc way).
    shards: Vec<Sender<ShardMsg>>,
    agg: Sender<PsRequest>,
    sync_count: Arc<AtomicU64>,
}

impl PsClient {
    /// Number of stat shards this client routes across.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Synchronous stats exchange: send local delta, adopt global reply.
    /// Returns the global snapshot for the touched functions plus any
    /// fresh globally detected events (§V trigger).
    pub fn sync(&self, app: u32, rank: u32, delta: &StatsTable) -> (StatsTable, Vec<GlobalEvent>) {
        if delta.is_empty() {
            return (StatsTable::new(), Vec::new());
        }
        let n = self.shards.len();
        let mut parts: Vec<Vec<(u32, RunStats)>> = vec![Vec::new(); n];
        for (fid, st) in delta.iter() {
            parts[shard_of(app, fid, n)].push((fid, *st));
        }
        self.sync_parts(app, rank, parts)
    }

    /// Routed sync from pre-partitioned sub-deltas (`parts[i]` goes to
    /// shard `i`). The TCP front-end calls this directly so shard groups
    /// carried on the wire are forwarded without re-hashing. Entries must
    /// be grouped by [`shard_of`] or the global view fragments.
    pub fn sync_parts(
        &self,
        app: u32,
        rank: u32,
        parts: Vec<Vec<(u32, RunStats)>>,
    ) -> (StatsTable, Vec<GlobalEvent>) {
        debug_assert_eq!(parts.len(), self.shards.len());
        if parts.iter().all(|p| p.is_empty()) {
            return (StatsTable::new(), Vec::new());
        }
        self.sync_count.fetch_add(1, Ordering::Relaxed);
        let (rtx, rrx) = channel();
        let mut expected = 0usize;
        for (i, part) in parts.into_iter().enumerate() {
            if part.is_empty() || i >= self.shards.len() {
                continue;
            }
            if self.shards[i]
                .send(ShardMsg::Sync { app, delta: part, reply: rtx.clone() })
                .is_ok()
            {
                expected += 1;
            }
        }
        drop(rtx);
        // Event fetch: an empty-delta Sync to the aggregator advances this
        // rank's cursor and returns undelivered global events. Sent before
        // collecting shard replies so the two legs overlap.
        let (etx, erx) = channel();
        let fetch_sent = self
            .agg
            .send(PsRequest::Sync { app, rank, delta: Vec::new(), reply: etx })
            .is_ok();
        let mut table = StatsTable::new();
        for _ in 0..expected {
            match rrx.recv() {
                Ok(entries) => {
                    for (fid, st) in entries {
                        table.replace(fid, st);
                    }
                }
                Err(_) => break,
            }
        }
        let events = if fetch_sent {
            erx.recv().map(|r: PsReply| r.global_events).unwrap_or_default()
        } else {
            Vec::new()
        };
        (table, events)
    }

    /// Fire-and-forget anomaly accounting.
    pub fn report(&self, stat: StepStat) {
        let _ = self.agg.send(PsRequest::Report(stat));
    }

    /// Force a viz publish (the merge stage folds in shard partials).
    pub fn publish(&self) {
        let _ = self.agg.send(PsRequest::Publish);
    }

    /// Stop the aggregator (it publishes a final snapshot first). The
    /// stat shards stay up until [`PsHandle::join`] so the final merge
    /// can still gather their partials.
    pub fn shutdown(&self) {
        let _ = self.agg.send(PsRequest::Shutdown);
    }
}

/// Joinable handle to a spawned constellation.
pub struct PsHandle {
    shard_txs: Vec<Sender<ShardMsg>>,
    agg_join: JoinHandle<ParameterServer>,
    merge_join: JoinHandle<()>,
    shard_joins: Vec<JoinHandle<HashMap<FuncKey, RunStats>>>,
    sync_count: Arc<AtomicU64>,
}

/// Merged final state of a sharded parameter server.
pub struct PsFinal {
    /// Final snapshot (ranks, totals, global events, function count).
    pub snapshot: VizSnapshot,
    /// The reunited global function-statistics view.
    pub global: HashMap<FuncKey, RunStats>,
    /// All globally detected events, chronological.
    pub global_events: Vec<GlobalEvent>,
    /// Routed (non-empty) syncs served.
    pub sync_count: u64,
}

impl PsFinal {
    /// Global statistics for one function.
    pub fn global_stats(&self, app: u32, fid: u32) -> Option<&RunStats> {
        self.global.get(&(app, fid))
    }

    /// Number of functions tracked globally.
    pub fn global_len(&self) -> usize {
        self.global.len()
    }
}

impl PsHandle {
    /// Tear down after [`PsClient::shutdown`] and merge the final state.
    ///
    /// Join order matters: the aggregator first (its final publish is
    /// queued to the merge stage), then the merge stage (which still
    /// queries the live shards for partials), then the shards.
    /// Panics if any server thread panicked.
    pub fn join(self) -> PsFinal {
        let mut agg = self.agg_join.join().expect("ps aggregator panicked");
        // Close the merge stage's job channel: the aggregator's viz
        // sender is the only producer.
        agg.detach_viz();
        self.merge_join.join().expect("ps merge stage panicked");
        for tx in &self.shard_txs {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        let mut global: HashMap<FuncKey, RunStats> = HashMap::new();
        for j in self.shard_joins {
            let part = j.join().expect("ps shard panicked");
            global.extend(part);
        }
        let mut snapshot = agg.snapshot();
        snapshot.functions_tracked = global.len() as u64;
        let global_events = agg.global_events().to_vec();
        PsFinal {
            snapshot,
            global,
            global_events,
            sync_count: self.sync_count.load(Ordering::Relaxed),
        }
    }
}

/// Spawn a sharded parameter server.
///
/// * `n_shards` — stat-shard threads (1 reproduces single-server
///   behaviour exactly);
/// * `viz_tx` — viz ingest channel for merged snapshots;
/// * `publish_every` — snapshot cadence in Report messages;
/// * `reports_per_step` — number of reporting ranks (the per-step quorum
///   for global-event detection).
pub fn spawn(
    n_shards: usize,
    viz_tx: Option<Sender<VizSnapshot>>,
    publish_every: usize,
    reports_per_step: usize,
) -> (PsClient, PsHandle) {
    let n = n_shards.max(1);
    let mut shard_txs: Vec<Sender<ShardMsg>> = Vec::with_capacity(n);
    let mut shard_joins = Vec::with_capacity(n);
    for i in 0..n {
        let (tx, rx): (Sender<ShardMsg>, Receiver<ShardMsg>) = channel();
        let join = std::thread::Builder::new()
            .name(format!("chimbuko-ps-shard-{i}"))
            .spawn(move || run_shard(rx))
            .expect("spawning ps shard");
        shard_txs.push(tx);
        shard_joins.push(join);
    }

    // Aggregator: a ParameterServer whose viz sender feeds the merge
    // stage instead of the viz channel directly.
    let (job_tx, job_rx) = channel::<VizSnapshot>();
    let (agg_tx, agg_rx): (Sender<PsRequest>, Receiver<PsRequest>) = channel();
    let agg_join = std::thread::Builder::new()
        .name("chimbuko-ps-agg".into())
        .spawn(move || {
            let mut ps = ParameterServer::new(Some(job_tx), publish_every, reports_per_step);
            while let Ok(req) = agg_rx.recv() {
                if !ps.handle(req) {
                    break;
                }
            }
            ps
        })
        .expect("spawning ps aggregator");

    // Merge stage: fold one partial per stat shard onto each aggregator
    // partial, then forward downstream. Commutative merges make the
    // arrival order irrelevant — no barrier anywhere.
    let merge_shards = shard_txs.clone();
    let merge_join = std::thread::Builder::new()
        .name("chimbuko-ps-merge".into())
        .spawn(move || {
            while let Ok(mut partial) = job_rx.recv() {
                let (ptx, prx) = channel();
                let mut expected = 0usize;
                for tx in &merge_shards {
                    if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                        expected += 1;
                    }
                }
                drop(ptx);
                for _ in 0..expected {
                    match prx.recv() {
                        Ok(p) => partial.merge(&p),
                        Err(_) => break,
                    }
                }
                if let Some(tx) = &viz_tx {
                    let _ = tx.send(partial);
                }
            }
        })
        .expect("spawning ps merge stage");

    let sync_count = Arc::new(AtomicU64::new(0));
    let client = PsClient {
        shards: shard_txs.clone(),
        agg: agg_tx,
        sync_count: sync_count.clone(),
    };
    let handle = PsHandle { shard_txs, agg_join, merge_join, shard_joins, sync_count };
    (client, handle)
}

/// One stat shard's loop: own the `shard_of == i` partition of the
/// global function statistics.
fn run_shard(rx: Receiver<ShardMsg>) -> HashMap<FuncKey, RunStats> {
    let mut table: HashMap<FuncKey, RunStats> = HashMap::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Sync { app, delta, reply } => {
                let mut out = Vec::with_capacity(delta.len());
                for (fid, st) in delta {
                    let g = table.entry((app, fid)).or_default();
                    g.merge(&st);
                    out.push((fid, *g));
                }
                let _ = reply.send(out);
            }
            ShardMsg::Snapshot { reply } => {
                let _ = reply.send(VizSnapshot {
                    functions_tracked: table.len() as u64,
                    ..VizSnapshot::default()
                });
            }
            ShardMsg::Shutdown => break,
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 7, 16] {
            for app in 0..3u32 {
                for fid in 0..300u32 {
                    let s = shard_of(app, fid, n);
                    assert!(s < n);
                    assert_eq!(s, shard_of(app, fid, n), "must be deterministic");
                }
            }
        }
        // One shard owns everything.
        assert_eq!(shard_of(9, 12345, 1), 0);
    }

    #[test]
    fn shard_of_spreads_keys() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for fid in 0..256u32 {
            counts[shard_of(0, fid, n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 256 / n / 3, "shard {i} starved: {c} of 256 keys");
        }
    }

    #[test]
    fn routed_sync_reassembles_full_reply() {
        let (client, handle) = spawn(4, None, usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..64u32 {
            delta.push(fid, fid as f64 + 1.0);
            delta.push(fid, fid as f64 + 3.0);
        }
        let (global, events) = client.sync(0, 0, &delta);
        assert!(events.is_empty());
        assert_eq!(global.len(), 64, "every touched function must come back");
        for fid in 0..64u32 {
            let st = global.get(fid).unwrap();
            assert_eq!(st.count(), 2);
            assert!((st.mean() - (fid as f64 + 2.0)).abs() < 1e-12);
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 64);
        assert_eq!(fin.snapshot.functions_tracked, 64);
        assert_eq!(fin.sync_count, 1);
    }

    #[test]
    fn merged_snapshots_reach_viz_channel() {
        let (vtx, vrx) = channel();
        let (client, handle) = spawn(3, Some(vtx), usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..24u32 {
            delta.push(fid, 10.0);
        }
        client.sync(0, 0, &delta);
        client.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 50,
            n_anomalies: 2,
            ts_range: (0, 9),
        });
        client.publish();
        // The published snapshot folds the aggregator partial (report
        // totals) with the stat-shard partials (function counts).
        let snap = vrx.recv().unwrap();
        assert_eq!(snap.total_anomalies, 2);
        assert_eq!(snap.total_executions, 50);
        assert_eq!(snap.functions_tracked, 24);
        assert_eq!(snap.ranks.len(), 1);
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.snapshot.total_anomalies, 2);
        // Final shutdown publish also reached the channel.
        let last = vrx.recv().unwrap();
        assert_eq!(last.total_anomalies, 2);
        assert!(vrx.recv().is_err(), "viz channel must close after join");
    }

    #[test]
    fn n1_matches_reference_inline() {
        // The same op sequence through a 1-shard constellation and the
        // single-threaded reference server must agree bit-for-bit.
        let (client, handle) = spawn(1, None, usize::MAX >> 1, 2);
        let mut reference = ParameterServer::new(None, usize::MAX >> 1, 2);
        for step in 0..6u64 {
            for rank in 0..2u32 {
                let stat = StepStat {
                    app: 0,
                    rank,
                    step,
                    n_executions: 40,
                    n_anomalies: (step % 2) * (rank as u64),
                    ts_range: (step, step + 1),
                };
                client.report(stat.clone());
                reference.handle(PsRequest::Report(stat));
                let mut delta = StatsTable::new();
                delta.push(rank, 100.0 + step as f64);
                delta.push(7, 5.0 * (step + 1) as f64);
                let (got, _) = client.sync(0, rank, &delta);
                let (rtx, rrx) = channel();
                reference.handle(PsRequest::Sync {
                    app: 0,
                    rank,
                    delta: delta.iter().map(|(f, s)| (f, *s)).collect(),
                    reply: rtx,
                });
                let want = rrx.recv().unwrap();
                for (fid, st) in want.global {
                    assert_eq!(got.get(fid), Some(&st), "fid {fid} diverged at step {step}");
                }
            }
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), reference.global_len());
        for (key, st) in reference.global_iter() {
            assert_eq!(fin.global.get(&key), Some(st));
        }
        assert_eq!(fin.snapshot.total_anomalies, reference.snapshot().total_anomalies);
        assert_eq!(fin.snapshot.total_executions, reference.snapshot().total_executions);
    }
}
