//! Hash-routed parameter-server shards (see the [`ps`](super) module
//! docs for the architecture).
//!
//! [`spawn`] starts the in-process constellation: N stat-shard threads,
//! one aggregator thread (a [`ParameterServer`] that never sees function
//! deltas), and one merge thread that folds partial snapshot deltas into
//! the viz ingest channel. [`spawn_with`] additionally accepts a list of
//! remote shard *endpoints* (`ps-shard-server` processes), in which case
//! the stat shards live in other processes and every shard connection is
//! a TCP socket instead of a channel.
//!
//! [`PsClient`] is the one router the on-node AD modules talk to — over
//! in-process channels, over per-shard TCP endpoints, or through a
//! single front-end (the degenerate single-endpoint deployment). The
//! connection kind is invisible above this module. [`PsHandle::join`]
//! tears the constellation down and returns the merged final state
//! ([`PsFinal`]).

use super::{
    FuncKey, GlobalEvent, ParameterServer, PsReply, PsRequest, StepStat, VizSnapshot,
};
use crate::stats::{RunStats, StatsTable};
use crate::util::net::Reconnector;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Stable shard routing: which of `n_shards` owns `(app, fid)`.
///
/// One [`splitmix64`](crate::util::rng::splitmix64) step over the packed
/// key — cheap, well-mixed, and identical on both sides of the wire
/// protocol (the TCP client groups deltas with this same function after
/// the hello handshake). The provDB's
/// [`prov_shard_of`](crate::provdb::prov_shard_of) shares the mixer.
pub fn shard_of(app: u32, fid: u32, n_shards: usize) -> usize {
    let mut key = ((app as u64) << 32) | fid as u64;
    (crate::util::rng::splitmix64(&mut key) % n_shards.max(1) as u64) as usize
}

/// Message to one stat shard.
pub(crate) enum ShardMsg {
    /// Batched sub-delta for this shard; replies with the merged global
    /// stats for exactly the functions in the sub-delta, plus the
    /// shard's view of the aggregator event version.
    Sync {
        app: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<ShardPart>,
    },
    /// Partial snapshot (function count + load counters) for the merge
    /// stage.
    Snapshot { reply: Sender<VizSnapshot> },
    /// Stop and return the owned partition.
    Shutdown,
}

/// A stat shard's sync reply: merged entries plus the piggybacked
/// aggregator event version (see the gating protocol in the module docs).
pub(crate) struct ShardPart {
    pub entries: Vec<(u32, RunStats)>,
    pub event_version: u64,
}

/// One pluggable shard connection: an in-process channel to a shard
/// thread, or a reconnecting TCP connection to a `ps-shard-server`
/// endpoint. The router treats both identically.
pub(crate) enum ShardConn {
    Local(Sender<ShardMsg>),
    Tcp(Mutex<Reconnector<super::net::ShardWire>>),
}

/// Connection to the aggregator/front-end: the in-process request
/// channel, or a reconnecting TCP connection to a `ps-server` front-end.
pub(crate) enum AggConn {
    Local(Sender<PsRequest>),
    Tcp(Mutex<Reconnector<super::net::AggWire>>),
}

/// How a [`PsClient`] reaches the stat shards.
pub(crate) enum Route {
    /// Per-shard connections (channels or TCP endpoints); the client
    /// gates the aggregator event fetch itself.
    Sharded(Arc<Vec<ShardConn>>),
    /// Everything behind one front-end endpoint: grouped sync frames,
    /// server-side routing and gating (the degenerate deployment).
    Frontend { n_shards: usize },
}

/// Per-(app, rank) event-gating state (see the module docs). Reports
/// are counted, not flagged: a sync samples `reports` and, after a
/// successful fetch, acknowledges exactly that many — so a report racing
/// in from another thread between the sample and the acknowledgement
/// still leaves `reports > acked_reports` and forces the next sync to
/// fetch (a boolean here would clobber the racing report's bit).
#[derive(Default)]
pub(crate) struct Gate {
    /// Reports this rank has sent (monotonic).
    reports: u64,
    /// Reports an aggregator event fetch has serialized behind.
    acked_reports: u64,
    /// Highest aggregator event version this rank has observed.
    seen: u64,
}

/// Cloneable router handle used by on-node AD modules — in-process and
/// remote clients are the *same type* over different connections.
///
/// `sync` splits the delta by [`shard_of`], batches one message per
/// touched shard, fans them out (pipelining writes before reads on TCP
/// connections), reassembles the reply client-side, and fetches
/// undelivered global events from the aggregator only when the version
/// gate says there may be any.
#[derive(Clone)]
pub struct PsClient {
    pub(crate) route: Route,
    pub(crate) agg: Arc<AggConn>,
    pub(crate) sync_count: Arc<AtomicU64>,
    /// Event-fetch messages sent to the aggregator (the gated leg).
    pub(crate) agg_fetches: Arc<AtomicU64>,
    pub(crate) gates: Arc<Mutex<HashMap<(u32, u32), Gate>>>,
}

impl Clone for Route {
    fn clone(&self) -> Route {
        match self {
            Route::Sharded(c) => Route::Sharded(c.clone()),
            Route::Frontend { n_shards } => Route::Frontend { n_shards: *n_shards },
        }
    }
}

/// Aggregate PS counters readable through the router (local constellation
/// or the front-end's wire stats) — the e2e tests compare these across
/// deployments.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PsStats {
    pub total_anomalies: u64,
    pub total_executions: u64,
    pub ranks: u32,
    pub event_version: u64,
    pub global_events: Vec<GlobalEvent>,
}

impl PsClient {
    /// Number of stat shards this client routes across.
    pub fn shard_count(&self) -> usize {
        match &self.route {
            Route::Sharded(c) => c.len(),
            Route::Frontend { n_shards } => *n_shards,
        }
    }

    /// Event-fetch messages this client has sent to the aggregator. In
    /// the no-events steady state (no reports, no version bumps) this
    /// stays at 0 while `sync` counts climb — the gating win the fig7
    /// endpoint sweep measures.
    pub fn agg_fetch_count(&self) -> u64 {
        self.agg_fetches.load(Ordering::Relaxed)
    }

    /// Routed (non-empty) syncs this client has issued.
    pub fn sync_count_value(&self) -> u64 {
        self.sync_count.load(Ordering::Relaxed)
    }

    /// Synchronous stats exchange: send local delta, adopt global reply.
    /// Returns the global snapshot for the touched functions plus any
    /// fresh globally detected events (§V trigger).
    pub fn sync(&self, app: u32, rank: u32, delta: &StatsTable) -> (StatsTable, Vec<GlobalEvent>) {
        if delta.is_empty() {
            return (StatsTable::new(), Vec::new());
        }
        let n = self.shard_count();
        let mut parts: Vec<Vec<(u32, RunStats)>> = vec![Vec::new(); n];
        for (fid, st) in delta.iter() {
            parts[shard_of(app, fid, n)].push((fid, *st));
        }
        self.sync_parts(app, rank, parts)
    }

    /// Routed sync from pre-partitioned sub-deltas (`parts[i]` goes to
    /// shard `i`). The TCP front-end calls this directly so shard groups
    /// carried on the wire are forwarded without re-hashing. Entries must
    /// be grouped by [`shard_of`] or the global view fragments.
    pub fn sync_parts(
        &self,
        app: u32,
        rank: u32,
        parts: Vec<Vec<(u32, RunStats)>>,
    ) -> (StatsTable, Vec<GlobalEvent>) {
        if parts.iter().all(|p| p.is_empty()) {
            return (StatsTable::new(), Vec::new());
        }
        self.sync_count.fetch_add(1, Ordering::Relaxed);
        let conns = match &self.route {
            Route::Sharded(c) => c.clone(),
            Route::Frontend { .. } => return self.sync_grouped_frontend(app, rank, &parts),
        };
        debug_assert_eq!(parts.len(), conns.len());
        let key = (app, rank);
        let (reports_now, acked, seen) = {
            let g = self.gates.lock().expect("ps gate lock");
            g.get(&key).map(|x| (x.reports, x.acked_reports, x.seen)).unwrap_or((0, 0, 0))
        };
        let dirty = reports_now > acked;

        // Event-fetch leg, sent *before* collecting shard replies when we
        // already know a fetch must happen (this rank reported since its
        // last aggregator contact), so the two legs overlap — and so the
        // fetch serializes behind the report in the aggregator's queue,
        // preserving exactly-once, next-sync delivery.
        let mut early: Option<Receiver<PsReply>> = None;
        if dirty {
            if let AggConn::Local(tx) = self.agg.as_ref() {
                let (etx, erx) = channel();
                let req = PsRequest::Sync { app, rank, delta: Vec::new(), reply: etx };
                if tx.send(req).is_ok() {
                    self.agg_fetches.fetch_add(1, Ordering::Relaxed);
                    early = Some(erx);
                }
            }
        }

        // Fan out: local shards get channel sends (their replies arrive
        // on `rrx`); TCP shards get pipelined writes — every request goes
        // out before any reply is read, with each connection's lock held
        // across its write→read window (acquired in shard-index order,
        // so concurrent clients cannot deadlock).
        let (rtx, rrx) = channel();
        let mut expected = 0usize;
        let mut tcp: Vec<(std::sync::MutexGuard<'_, Reconnector<super::net::ShardWire>>, bool)> =
            Vec::new();
        for (i, part) in parts.into_iter().enumerate() {
            if part.is_empty() || i >= conns.len() {
                continue;
            }
            match &conns[i] {
                ShardConn::Local(tx) => {
                    if tx.send(ShardMsg::Sync { app, delta: part, reply: rtx.clone() }).is_ok() {
                        expected += 1;
                    }
                }
                ShardConn::Tcp(m) => {
                    let mut g = m.lock().expect("ps shard conn lock");
                    let ok = match g.get() {
                        Ok(w) => match w.send_sync(app, &part) {
                            Ok(()) => true,
                            Err(e) => {
                                crate::log_warn!("ps", "shard sync send failed: {e:#}");
                                g.fail();
                                false
                            }
                        },
                        Err(e) => {
                            crate::log_warn!("ps", "shard unreachable: {e:#}");
                            false
                        }
                    };
                    tcp.push((g, ok));
                }
            }
        }
        drop(rtx);

        let mut table = StatsTable::new();
        let mut vmax = 0u64;
        for (mut g, ok) in tcp {
            if !ok {
                continue;
            }
            if let Ok(w) = g.get() {
                match w.recv_sync() {
                    Ok((entries, ver)) => {
                        for (fid, st) in entries {
                            table.replace(fid, st);
                        }
                        vmax = vmax.max(ver);
                    }
                    Err(e) => {
                        crate::log_warn!("ps", "shard sync reply failed: {e:#}");
                        g.fail();
                    }
                }
            }
        }
        for _ in 0..expected {
            match rrx.recv() {
                Ok(part) => {
                    for (fid, st) in part.entries {
                        table.replace(fid, st);
                    }
                    vmax = vmax.max(part.event_version);
                }
                Err(_) => break,
            }
        }

        // Version-gated event fetch: only when this rank reported since
        // its last aggregator contact, or a shard piggybacked a version
        // newer than anything this rank has seen.
        let fetched: Option<(u64, Vec<GlobalEvent>)> = if let Some(erx) = early {
            erx.recv().ok().map(|r| (r.event_version, r.global_events))
        } else if dirty || vmax > seen {
            self.agg_fetches.fetch_add(1, Ordering::Relaxed);
            self.fetch_events_inner(app, rank)
        } else {
            None
        };
        let (events, did_fetch, fetched_ver) = match fetched {
            Some((ver, evs)) => (evs, true, ver),
            None => (Vec::new(), false, 0),
        };
        if did_fetch {
            // Advance the gate only on a *successful* fetch: if the
            // aggregator was unreachable, recording the piggybacked
            // version now would make every later sync compare equal and
            // silently skip the delivery forever; leaving the gate
            // untouched makes the next sync retry. Acknowledge only the
            // reports sampled above — one racing in since then keeps
            // `reports > acked_reports` and forces the next fetch.
            let mut g = self.gates.lock().expect("ps gate lock");
            let e = g.entry(key).or_default();
            e.acked_reports = e.acked_reports.max(reports_now);
            e.seen = e.seen.max(vmax).max(fetched_ver);
        }
        (table, events)
    }

    /// Degenerate single-endpoint route: one grouped frame to the
    /// front-end, which routes server-side (and gates the event fetch
    /// with *its* in-process client, so the reply still carries fresh
    /// events exactly once).
    fn sync_grouped_frontend(
        &self,
        app: u32,
        rank: u32,
        parts: &[Vec<(u32, RunStats)>],
    ) -> (StatsTable, Vec<GlobalEvent>) {
        let AggConn::Tcp(m) = self.agg.as_ref() else {
            return (StatsTable::new(), Vec::new());
        };
        match m.lock().expect("ps agg conn lock").with(|w| w.sync_grouped(app, rank, parts)) {
            Ok((entries, events)) => {
                let mut table = StatsTable::new();
                for (fid, st) in entries {
                    table.replace(fid, st);
                }
                (table, events)
            }
            Err(e) => {
                crate::log_warn!("ps", "front-end sync failed (will reconnect): {e:#}");
                (StatsTable::new(), Vec::new())
            }
        }
    }

    /// One event-fetch round-trip to the aggregator (advances this
    /// rank's delivery cursor). Returns the aggregator's event version
    /// plus the events this rank had not yet seen.
    fn fetch_events_inner(&self, app: u32, rank: u32) -> Option<(u64, Vec<GlobalEvent>)> {
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let (etx, erx) = channel();
                tx.send(PsRequest::Sync { app, rank, delta: Vec::new(), reply: etx }).ok()?;
                erx.recv().ok().map(|r| (r.event_version, r.global_events))
            }
            AggConn::Tcp(m) => {
                match m.lock().expect("ps agg conn lock").with(|w| w.fetch_events(app, rank)) {
                    Ok(v) => Some(v),
                    Err(e) => {
                        crate::log_warn!("ps", "event fetch failed (will reconnect): {e:#}");
                        None
                    }
                }
            }
        }
    }

    /// Explicit event fetch for this rank (the TCP front-end serves
    /// `KIND_EVENT_FETCH` through this). Does not touch the client-side
    /// gate — the caller owns its own gating.
    pub fn fetch_events(&self, app: u32, rank: u32) -> (u64, Vec<GlobalEvent>) {
        self.fetch_events_inner(app, rank).unwrap_or((0, Vec::new()))
    }

    /// Fire-and-forget anomaly accounting. Marks this rank's gate dirty:
    /// its next sync *must* round-trip to the aggregator (the report may
    /// complete a step quorum and flag a global event, and next-sync
    /// delivery order requires the fetch to serialize behind it).
    pub fn report(&self, stat: StepStat) {
        {
            let mut g = self.gates.lock().expect("ps gate lock");
            g.entry((stat.app, stat.rank)).or_default().reports += 1;
        }
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let _ = tx.send(PsRequest::Report(stat));
            }
            AggConn::Tcp(m) => {
                if let Err(e) = m.lock().expect("ps agg conn lock").with(|w| w.report(&stat)) {
                    crate::log_warn!("ps", "report failed (will reconnect): {e:#}");
                }
            }
        }
    }

    /// Aggregate PS counters (totals, rank count, event set). `None`
    /// when the aggregator is unreachable.
    pub fn stats(&self) -> Option<PsStats> {
        match self.agg.as_ref() {
            AggConn::Local(tx) => {
                let (qtx, qrx) = channel();
                tx.send(PsRequest::Query { reply: qtx }).ok()?;
                let snap = qrx.recv().ok()?;
                Some(PsStats {
                    total_anomalies: snap.total_anomalies,
                    total_executions: snap.total_executions,
                    ranks: snap.ranks.len() as u32,
                    event_version: snap.global_events.len() as u64,
                    global_events: snap.global_events,
                })
            }
            AggConn::Tcp(m) => {
                m.lock().expect("ps agg conn lock").with(|w| w.ps_stats()).ok()
            }
        }
    }

    /// Force a viz publish (the merge stage folds in shard partials).
    /// No-op through a TCP front-end: remote clients do not drive the
    /// server's publish cadence.
    pub fn publish(&self) {
        if let AggConn::Local(tx) = self.agg.as_ref() {
            let _ = tx.send(PsRequest::Publish);
        }
    }

    /// Stop the aggregator (it publishes a final snapshot first). The
    /// stat shards stay up until [`PsHandle::join`] so the final merge
    /// can still gather their partials. No-op through a TCP front-end.
    pub fn shutdown(&self) {
        if let AggConn::Local(tx) = self.agg.as_ref() {
            let _ = tx.send(PsRequest::Shutdown);
        }
    }
}

/// Joinable handle to a spawned constellation.
pub struct PsHandle {
    shard_txs: Vec<Sender<ShardMsg>>,
    conns: Arc<Vec<ShardConn>>,
    agg_join: JoinHandle<ParameterServer>,
    merge_join: JoinHandle<()>,
    shard_joins: Vec<JoinHandle<HashMap<FuncKey, RunStats>>>,
    sync_count: Arc<AtomicU64>,
    version: Arc<AtomicU64>,
}

/// Merged final state of a sharded parameter server.
pub struct PsFinal {
    /// Final snapshot (ranks, totals, global events, function count).
    pub snapshot: VizSnapshot,
    /// The reunited global function-statistics view. Covers the shards
    /// this process hosts; remote shard endpoints contribute only their
    /// function *count* (fetched at join time) to
    /// `snapshot.functions_tracked`.
    pub global: HashMap<FuncKey, RunStats>,
    /// All globally detected events, chronological.
    pub global_events: Vec<GlobalEvent>,
    /// Routed (non-empty) syncs served.
    pub sync_count: u64,
}

impl PsFinal {
    /// Global statistics for one function.
    pub fn global_stats(&self, app: u32, fid: u32) -> Option<&RunStats> {
        self.global.get(&(app, fid))
    }

    /// Number of functions tracked globally.
    pub fn global_len(&self) -> usize {
        self.global.len()
    }
}

impl PsHandle {
    /// Serve every *local* stat shard on its own TCP endpoint (ephemeral
    /// ports); returns one server handle per shard, index-aligned. The
    /// addresses feed `PsTcpServer::start_with_topology` so a front-end
    /// can hand clients the shard→addr map.
    pub fn serve_shard_endpoints(&self) -> anyhow::Result<Vec<super::net::PsShardTcpServer>> {
        (0..self.shard_txs.len())
            .map(|i| self.serve_shard_endpoint_at(i, "127.0.0.1:0"))
            .collect()
    }

    /// Serve one local stat shard at `addr` (tests restart a killed
    /// endpoint on its old port with this, keeping the shard state).
    pub fn serve_shard_endpoint_at(
        &self,
        shard: usize,
        addr: &str,
    ) -> anyhow::Result<super::net::PsShardTcpServer> {
        anyhow::ensure!(
            shard < self.shard_txs.len(),
            "shard {shard} out of range ({} local shards)",
            self.shard_txs.len()
        );
        super::net::PsShardTcpServer::start_wrapping(
            addr,
            self.shard_txs[shard].clone(),
            shard as u32,
            self.shard_txs.len() as u32,
            self.version.clone(),
        )
    }

    /// Tear down after [`PsClient::shutdown`] and merge the final state.
    ///
    /// Join order matters: the aggregator first (its final publish is
    /// queued to the merge stage), then the merge stage (which still
    /// queries the live shards for partials), then the shards.
    /// Panics if any server thread panicked.
    pub fn join(self) -> PsFinal {
        let mut agg = self.agg_join.join().expect("ps aggregator panicked");
        // Close the merge stage's job channel: the aggregator's viz
        // sender is the only producer.
        agg.detach_viz();
        self.merge_join.join().expect("ps merge stage panicked");
        // Gather each shard's final partial (function counts + load
        // counters) while the shards are still alive, so the final
        // snapshot carries per-shard loads like every published delta —
        // `/api/ps_stats` serves these after a finished run too.
        let mut shard_loads: Vec<super::ShardLoad> = Vec::new();
        let mut remote_functions = 0u64;
        let (ptx, prx) = channel();
        let mut expected = 0usize;
        for conn in self.conns.iter() {
            match conn {
                ShardConn::Local(tx) => {
                    if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                        expected += 1;
                    }
                }
                ShardConn::Tcp(m) => {
                    if let Ok(p) = m.lock().expect("ps shard conn lock").with(|w| w.snapshot()) {
                        remote_functions += p.functions_tracked;
                        shard_loads.extend(p.shard_loads.iter().copied());
                    }
                }
            }
        }
        drop(ptx);
        for _ in 0..expected {
            match prx.recv() {
                Ok(p) => shard_loads.extend(p.shard_loads.iter().copied()),
                Err(_) => break,
            }
        }
        shard_loads.sort_by_key(|l| l.shard);
        for tx in &self.shard_txs {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        let mut global: HashMap<FuncKey, RunStats> = HashMap::new();
        for j in self.shard_joins {
            let part = j.join().expect("ps shard panicked");
            global.extend(part);
        }
        let mut snapshot = agg.snapshot();
        snapshot.functions_tracked = global.len() as u64 + remote_functions;
        snapshot.shard_loads = shard_loads;
        let global_events = agg.global_events().to_vec();
        PsFinal {
            snapshot,
            global,
            global_events,
            sync_count: self.sync_count.load(Ordering::Relaxed),
        }
    }
}

/// Options for [`spawn_with`]: the full topology/cadence knob set.
#[derive(Default)]
pub struct PsOpts {
    /// Local stat-shard threads (ignored when `endpoints` is non-empty;
    /// 0 behaves as 1).
    pub shards: usize,
    /// Remote shard endpoints (`ps-shard-server` addresses), index ==
    /// shard id. Non-empty switches the constellation to routed TCP
    /// shard connections.
    pub endpoints: Vec<String>,
    /// Viz ingest channel for merged snapshot deltas.
    pub viz_tx: Option<Sender<VizSnapshot>>,
    /// Snapshot cadence in Report messages (0 behaves as 1).
    pub publish_every: usize,
    /// Wall-clock snapshot cadence in milliseconds (the paper's 1 s);
    /// 0 disables. Runs *alongside* `publish_every`: whichever fires
    /// first publishes, so viz freshness no longer depends on rank count.
    pub publish_interval_ms: u64,
    /// Reports expected per step (the per-step quorum for global-event
    /// detection).
    pub reports_per_step: usize,
}

/// Spawn a sharded parameter server with in-process shards — see
/// [`spawn_with`] for the full option set (remote shard endpoints,
/// wall-clock publish cadence).
///
/// * `n_shards` — stat-shard threads (1 reproduces single-server
///   behaviour exactly);
/// * `viz_tx` — viz ingest channel for merged snapshots;
/// * `publish_every` — snapshot cadence in Report messages;
/// * `reports_per_step` — number of reporting ranks (the per-step quorum
///   for global-event detection).
pub fn spawn(
    n_shards: usize,
    viz_tx: Option<Sender<VizSnapshot>>,
    publish_every: usize,
    reports_per_step: usize,
) -> (PsClient, PsHandle) {
    spawn_with(PsOpts {
        shards: n_shards,
        viz_tx,
        publish_every,
        reports_per_step,
        ..PsOpts::default()
    })
    .expect("spawning local parameter server cannot fail")
}

/// Spawn a parameter-server constellation per `opts`.
///
/// With `endpoints` empty this is the in-process layout ([`spawn`]).
/// With endpoints, each stat shard is a `ps-shard-server` process
/// reached over TCP: the aggregator, merge stage, and rank/step timeline
/// stay here (the front-end), shard connections are dialed eagerly
/// (fail fast on a bad address) and reconnect with backoff afterwards,
/// and the aggregator pushes event-version bumps to every shard endpoint
/// so piggybacked gating works across processes.
pub fn spawn_with(opts: PsOpts) -> anyhow::Result<(PsClient, PsHandle)> {
    let version = Arc::new(AtomicU64::new(0));
    let mut conns: Vec<ShardConn> = Vec::new();
    let mut shard_txs: Vec<Sender<ShardMsg>> = Vec::new();
    let mut shard_joins = Vec::new();
    if opts.endpoints.is_empty() {
        let n = opts.shards.max(1);
        for i in 0..n {
            let (tx, rx): (Sender<ShardMsg>, Receiver<ShardMsg>) = channel();
            let ver = version.clone();
            let join = std::thread::Builder::new()
                .name(format!("chimbuko-ps-shard-{i}"))
                .spawn(move || run_shard(rx, i as u32, ver))
                .expect("spawning ps shard");
            conns.push(ShardConn::Local(tx.clone()));
            shard_txs.push(tx);
            shard_joins.push(join);
        }
    } else {
        let n = opts.endpoints.len();
        for (i, ep) in opts.endpoints.iter().enumerate() {
            let wire = super::net::ShardWire::connect(ep, i as u32, n as u32)?;
            let (id, total) = (i as u32, n as u32);
            conns.push(ShardConn::Tcp(Mutex::new(Reconnector::seeded(
                ep,
                move |a: &str| super::net::ShardWire::connect(a, id, total),
                wire,
            ))));
        }
    }
    let conns = Arc::new(conns);

    // Aggregator: a ParameterServer whose viz sender feeds the merge
    // stage instead of the viz channel directly. It also owns the
    // event-version mirror: after every handled request the version is
    // stored for local shards (shared atomic) and pushed to remote shard
    // endpoints when it changed.
    let (job_tx, job_rx) = channel::<VizSnapshot>();
    let (agg_tx, agg_rx): (Sender<PsRequest>, Receiver<PsRequest>) = channel();
    let publish_every = opts.publish_every;
    let reports_per_step = opts.reports_per_step;
    let interval_ms = opts.publish_interval_ms;
    let push_conns = conns.clone();
    let agg_version = version.clone();
    let agg_join = std::thread::Builder::new()
        .name("chimbuko-ps-agg".into())
        .spawn(move || {
            let mut ps = ParameterServer::new(Some(job_tx), publish_every, reports_per_step);
            let mut running = true;
            let mut last_interval_pub = Instant::now();
            let mut last_ver = 0u64;
            while running {
                let req = if interval_ms == 0 {
                    match agg_rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => break,
                    }
                } else {
                    let budget = Duration::from_millis(interval_ms)
                        .saturating_sub(last_interval_pub.elapsed());
                    match agg_rx.recv_timeout(budget.max(Duration::from_millis(1))) {
                        Ok(r) => Some(r),
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                };
                match req {
                    Some(r) => {
                        if !ps.handle(r) {
                            running = false;
                        }
                        // Wall-clock cadence must also fire under
                        // sustained traffic (recv_timeout never times
                        // out while messages keep arriving), so check
                        // the interval after every handled message too.
                        if interval_ms > 0
                            && last_interval_pub.elapsed() >= Duration::from_millis(interval_ms)
                        {
                            if ps.pending_publish() {
                                ps.publish();
                            }
                            last_interval_pub = Instant::now();
                        }
                    }
                    None => {
                        // Idle tick: publish only when something new
                        // arrived since the last snapshot.
                        if ps.pending_publish() {
                            ps.publish();
                        }
                        last_interval_pub = Instant::now();
                    }
                }
                let v = ps.event_version();
                if v != last_ver {
                    agg_version.store(v, Ordering::SeqCst);
                    for conn in push_conns.iter() {
                        if let ShardConn::Tcp(m) = conn {
                            if let Err(e) = m
                                .lock()
                                .expect("ps shard conn lock")
                                .with(|w| w.push_version(v))
                            {
                                crate::log_warn!("ps", "version push failed: {e:#}");
                            }
                        }
                    }
                    last_ver = v;
                }
            }
            ps
        })
        .expect("spawning ps aggregator");

    // Merge stage: fold one partial per stat shard onto each aggregator
    // snapshot delta, then forward downstream. Commutative merges make
    // the arrival order irrelevant — no barrier anywhere.
    let merge_conns = conns.clone();
    let viz_tx = opts.viz_tx;
    let merge_join = std::thread::Builder::new()
        .name("chimbuko-ps-merge".into())
        .spawn(move || {
            while let Ok(mut partial) = job_rx.recv() {
                let (ptx, prx) = channel();
                let mut expected = 0usize;
                for conn in merge_conns.iter() {
                    match conn {
                        ShardConn::Local(tx) => {
                            if tx.send(ShardMsg::Snapshot { reply: ptx.clone() }).is_ok() {
                                expected += 1;
                            }
                        }
                        ShardConn::Tcp(m) => {
                            match m.lock().expect("ps shard conn lock").with(|w| w.snapshot()) {
                                Ok(p) => {
                                    let _ = ptx.send(p);
                                    expected += 1;
                                }
                                Err(e) => {
                                    crate::log_warn!("ps", "shard snapshot failed: {e:#}");
                                }
                            }
                        }
                    }
                }
                drop(ptx);
                for _ in 0..expected {
                    match prx.recv() {
                        Ok(p) => partial.merge(&p),
                        Err(_) => break,
                    }
                }
                if let Some(tx) = &viz_tx {
                    let _ = tx.send(partial);
                }
            }
        })
        .expect("spawning ps merge stage");

    let sync_count = Arc::new(AtomicU64::new(0));
    let client = PsClient {
        route: Route::Sharded(conns.clone()),
        agg: Arc::new(AggConn::Local(agg_tx)),
        sync_count: sync_count.clone(),
        agg_fetches: Arc::new(AtomicU64::new(0)),
        gates: Arc::new(Mutex::new(HashMap::new())),
    };
    let handle = PsHandle {
        shard_txs,
        conns,
        agg_join,
        merge_join,
        shard_joins,
        sync_count,
        version,
    };
    Ok((client, handle))
}

/// One stat shard's loop: own the `shard_of == i` partition of the
/// global function statistics, count its load, and piggyback the
/// aggregator event version (shared atomic locally; updated by version
/// pushes in a standalone `ps-shard-server`).
pub(crate) fn run_shard(
    rx: Receiver<ShardMsg>,
    shard_id: u32,
    version: Arc<AtomicU64>,
) -> HashMap<FuncKey, RunStats> {
    let mut table: HashMap<FuncKey, RunStats> = HashMap::new();
    let mut syncs = 0u64;
    let mut merges = 0u64;
    while let Ok(msg) = rx.recv() {
        match msg {
            ShardMsg::Sync { app, delta, reply } => {
                syncs += 1;
                let mut out = Vec::with_capacity(delta.len());
                for (fid, st) in delta {
                    let g = table.entry((app, fid)).or_default();
                    g.merge(&st);
                    merges += 1;
                    out.push((fid, *g));
                }
                let _ = reply.send(ShardPart {
                    entries: out,
                    event_version: version.load(Ordering::SeqCst),
                });
            }
            ShardMsg::Snapshot { reply } => {
                let _ = reply.send(VizSnapshot {
                    functions_tracked: table.len() as u64,
                    shard_loads: vec![super::ShardLoad {
                        shard: shard_id,
                        syncs,
                        merges,
                        functions: table.len() as u64,
                    }],
                    ..VizSnapshot::default()
                });
            }
            ShardMsg::Shutdown => break,
        }
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_of_is_stable_and_in_range() {
        for n in [1usize, 2, 4, 7, 16] {
            for app in 0..3u32 {
                for fid in 0..300u32 {
                    let s = shard_of(app, fid, n);
                    assert!(s < n);
                    assert_eq!(s, shard_of(app, fid, n), "must be deterministic");
                }
            }
        }
        // One shard owns everything.
        assert_eq!(shard_of(9, 12345, 1), 0);
    }

    #[test]
    fn shard_of_spreads_keys() {
        let n = 4;
        let mut counts = vec![0usize; n];
        for fid in 0..256u32 {
            counts[shard_of(0, fid, n)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 256 / n / 3, "shard {i} starved: {c} of 256 keys");
        }
    }

    #[test]
    fn routed_sync_reassembles_full_reply() {
        let (client, handle) = spawn(4, None, usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..64u32 {
            delta.push(fid, fid as f64 + 1.0);
            delta.push(fid, fid as f64 + 3.0);
        }
        let (global, events) = client.sync(0, 0, &delta);
        assert!(events.is_empty());
        assert_eq!(global.len(), 64, "every touched function must come back");
        for fid in 0..64u32 {
            let st = global.get(fid).unwrap();
            assert_eq!(st.count(), 2);
            assert!((st.mean() - (fid as f64 + 2.0)).abs() < 1e-12);
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), 64);
        assert_eq!(fin.snapshot.functions_tracked, 64);
        assert_eq!(fin.sync_count, 1);
    }

    #[test]
    fn merged_snapshots_reach_viz_channel() {
        let (vtx, vrx) = std::sync::mpsc::channel();
        let (client, handle) = spawn(3, Some(vtx), usize::MAX >> 1, 1);
        let mut delta = StatsTable::new();
        for fid in 0..24u32 {
            delta.push(fid, 10.0);
        }
        client.sync(0, 0, &delta);
        client.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 50,
            n_anomalies: 2,
            ts_range: (0, 9),
        });
        client.publish();
        // The published snapshot delta folds the aggregator partial
        // (report totals, changed ranks) with the stat-shard partials
        // (function counts + load counters).
        let snap = vrx.recv().unwrap();
        assert!(snap.delta, "published snapshots are deltas");
        assert_eq!(snap.total_anomalies, 2);
        assert_eq!(snap.total_executions, 50);
        assert_eq!(snap.functions_tracked, 24);
        assert_eq!(snap.ranks.len(), 1);
        assert_eq!(snap.shard_loads.len(), 3, "one load entry per shard");
        let total_merges: u64 = snap.shard_loads.iter().map(|l| l.merges).sum();
        assert_eq!(total_merges, 24);
        let total_syncs: u64 = snap.shard_loads.iter().map(|l| l.syncs).sum();
        assert_eq!(total_syncs, 3, "the routed sync touched every shard once");
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.snapshot.total_anomalies, 2);
        // The final snapshot carries the load counters too (this is what
        // /api/ps_stats serves after a finished run).
        assert_eq!(fin.snapshot.shard_loads.len(), 3);
        // Final shutdown publish also reached the channel; it is a delta
        // with no new ranks (nothing changed since the explicit publish).
        let last = vrx.recv().unwrap();
        assert_eq!(last.total_anomalies, 2);
        assert!(last.ranks.is_empty(), "unchanged ranks stay out of deltas");
        assert!(vrx.recv().is_err(), "viz channel must close after join");
    }

    #[test]
    fn n1_matches_reference_inline() {
        // The same op sequence through a 1-shard constellation and the
        // single-threaded reference server must agree bit-for-bit.
        let (client, handle) = spawn(1, None, usize::MAX >> 1, 2);
        let mut reference = ParameterServer::new(None, usize::MAX >> 1, 2);
        for step in 0..6u64 {
            for rank in 0..2u32 {
                let stat = StepStat {
                    app: 0,
                    rank,
                    step,
                    n_executions: 40,
                    n_anomalies: (step % 2) * (rank as u64),
                    ts_range: (step, step + 1),
                };
                client.report(stat.clone());
                reference.handle(PsRequest::Report(stat));
                let mut delta = StatsTable::new();
                delta.push(rank, 100.0 + step as f64);
                delta.push(7, 5.0 * (step + 1) as f64);
                let (got, _) = client.sync(0, rank, &delta);
                let (rtx, rrx) = channel();
                reference.handle(PsRequest::Sync {
                    app: 0,
                    rank,
                    delta: delta.iter().map(|(f, s)| (f, *s)).collect(),
                    reply: rtx,
                });
                let want = rrx.recv().unwrap();
                for (fid, st) in want.global {
                    assert_eq!(got.get(fid), Some(&st), "fid {fid} diverged at step {step}");
                }
            }
        }
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), reference.global_len());
        for (key, st) in reference.global_iter() {
            assert_eq!(fin.global.get(&key), Some(st));
        }
        assert_eq!(fin.snapshot.total_anomalies, reference.snapshot().total_anomalies);
        assert_eq!(fin.snapshot.total_executions, reference.snapshot().total_executions);
    }

    #[test]
    fn event_fetch_is_gated_without_reports() {
        // Sync-only load: no reports, no events — the gated client never
        // round-trips to the aggregator (the steady state the endpoint
        // sweep measures).
        let (client, handle) = spawn(2, None, usize::MAX >> 1, 1);
        for rank in 0..4u32 {
            let mut delta = StatsTable::new();
            delta.push(rank, 1.0);
            delta.push(rank + 100, 2.0);
            client.sync(0, rank, &delta);
        }
        assert_eq!(client.agg_fetch_count(), 0, "no reports → no event fetches");
        // A report makes the next sync fetch (dirty gate), exactly once.
        client.report(StepStat {
            app: 0,
            rank: 0,
            step: 0,
            n_executions: 1,
            n_anomalies: 0,
            ts_range: (0, 1),
        });
        let mut delta = StatsTable::new();
        delta.push(1, 1.0);
        client.sync(0, 0, &delta);
        assert_eq!(client.agg_fetch_count(), 1, "dirty rank must fetch once");
        client.sync(0, 0, &delta);
        assert_eq!(client.agg_fetch_count(), 1, "clean rank must not fetch again");
        client.shutdown();
        handle.join();
    }

    #[test]
    fn wall_clock_publish_cadence() {
        // publish_every is effectively infinite; the 20 ms wall-clock
        // cadence must still flush a snapshot after a report arrives.
        let (vtx, vrx) = std::sync::mpsc::channel();
        let (client, handle) = spawn_with(PsOpts {
            shards: 1,
            viz_tx: Some(vtx),
            publish_every: usize::MAX >> 1,
            publish_interval_ms: 20,
            reports_per_step: 1,
            ..PsOpts::default()
        })
        .unwrap();
        client.report(StepStat {
            app: 0,
            rank: 3,
            step: 0,
            n_executions: 10,
            n_anomalies: 1,
            ts_range: (0, 1),
        });
        let snap = vrx
            .recv_timeout(Duration::from_secs(5))
            .expect("interval publish must fire without an explicit Publish");
        assert!(snap.delta);
        assert_eq!(snap.total_anomalies, 1);
        assert_eq!(snap.ranks.len(), 1);
        client.shutdown();
        handle.join();
    }

    #[test]
    fn query_stats_through_router() {
        let (client, handle) = spawn(2, None, usize::MAX >> 1, 1);
        client.report(StepStat {
            app: 0,
            rank: 1,
            step: 0,
            n_executions: 30,
            n_anomalies: 4,
            ts_range: (0, 1),
        });
        let stats = client.stats().expect("local stats");
        assert_eq!(stats.total_anomalies, 4);
        assert_eq!(stats.total_executions, 30);
        assert_eq!(stats.ranks, 1);
        assert_eq!(stats.event_version, 0);
        client.shutdown();
        handle.join();
    }
}
