//! Epoch-versioned shard **placement**: the routing table that says which
//! shard owns which key, for both the parameter server (keys are
//! `(app, fid)` function statistics) and the provenance database (keys
//! are `(app, rank)` partitions).
//!
//! Before this module, placement was a frozen hash (`ps::shard_of`,
//! `provdb::prov_shard_of`): one `splitmix64` step modulo the shard
//! count. That is cheap and uniform over *keys*, but load is not uniform
//! over keys — a single hot function (`md_forces` in the paper's NWChem
//! runs) pins one shard while its siblings idle, and a frozen hash gives
//! the system no way to react.
//!
//! [`Placement`] makes the routing table first-class data:
//!
//! * keys hash to one of [`SLOTS`] fixed **slots**
//!   ([`Placement::slot_of`] — the same `splitmix64` mixing as before);
//! * a table maps every slot to its owning shard;
//! * the table is versioned by a monotonic **epoch**. Epoch 0 is the
//!   deterministic default (`slot % n_shards`), which is what the free
//!   functions `shard_of`/`prov_shard_of` now compute — no behaviour
//!   change for deployments that never rebalance.
//!
//! A rebalancer produces a successor table with [`Placement::with_moves`]
//! (slot reassignments, epoch + 1). Every sync frame in the PS wire
//! protocol carries the sender's epoch; a shard that sees a frame from a
//! different epoch replies `Rerouted`, which makes the client refresh its
//! table and retry — see `ps::shard` for the migration handshake that
//! moves the affected state between shards before a new epoch commits.

use crate::util::rng::splitmix64;
use crate::util::wire::Cursor;
use anyhow::{bail, Result};

/// Number of routing slots. Keys hash uniformly onto slots; slots are the
/// unit of reassignment. 256 gives a rebalancer fine-grained moves (at 8
/// shards each owns 32 slots) while keeping the table one page.
pub const SLOTS: usize = 256;

/// Epoch-versioned slot → shard routing table.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Placement {
    epoch: u64,
    n_shards: u32,
    /// `slots[s]` = shard owning slot `s`; length is always [`SLOTS`].
    slots: Vec<u32>,
}

impl Placement {
    /// The epoch-0 default: slot `s` belongs to shard `s % n_shards` —
    /// even, deterministic, and identical on every node without any
    /// coordination.
    pub fn new(n_shards: usize) -> Placement {
        let n = n_shards.max(1) as u32;
        Placement {
            epoch: 0,
            n_shards: n,
            slots: (0..SLOTS as u32).map(|s| s % n).collect(),
        }
    }

    /// Monotonic table version. Two tables with the same epoch (from the
    /// same lineage) are identical.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards as usize
    }

    /// Which slot a key hashes to — placement-independent (one
    /// `splitmix64` step over the packed key, stable across epochs; only
    /// slot *ownership* ever changes).
    #[inline]
    pub fn slot_of(app: u32, id: u32) -> usize {
        let mut key = ((app as u64) << 32) | id as u64;
        (splitmix64(&mut key) % SLOTS as u64) as usize
    }

    /// Which shard owns a key under this table.
    #[inline]
    pub fn shard_of(&self, app: u32, id: u32) -> usize {
        self.slots[Self::slot_of(app, id)] as usize
    }

    /// Which shard owns a slot under this table.
    #[inline]
    pub fn shard_of_slot(&self, slot: usize) -> usize {
        self.slots[slot] as usize
    }

    /// The epoch-0 routing for a key, without building a table — the
    /// shared default behind the free `ps::shard_of` and
    /// `provdb::prov_shard_of` helpers.
    #[inline]
    pub fn default_shard_of(app: u32, id: u32, n_shards: usize) -> usize {
        Self::slot_of(app, id) % n_shards.max(1)
    }

    /// Successor table: apply `moves` (slot → new shard) and bump the
    /// epoch. Rejects out-of-range slots/shards; no-op moves are allowed
    /// (the plan may be conservative) but at least one real move is
    /// required — an epoch bump must mean the table changed.
    pub fn with_moves(&self, moves: &[(usize, u32)]) -> Result<Placement> {
        let mut next = self.clone();
        let mut changed = false;
        for &(slot, shard) in moves {
            if slot >= SLOTS {
                bail!("slot {slot} out of range (0..{SLOTS})");
            }
            if shard >= self.n_shards {
                bail!("shard {shard} out of range (0..{})", self.n_shards);
            }
            changed |= next.slots[slot] != shard;
            next.slots[slot] = shard;
        }
        if !changed {
            bail!("placement moves are all no-ops");
        }
        next.epoch = self.epoch + 1;
        Ok(next)
    }

    /// Slots owned by `shard` under this table.
    pub fn slots_of_shard(&self, shard: u32) -> Vec<usize> {
        (0..SLOTS).filter(|&s| self.slots[s] == shard).collect()
    }

    /// Slots `shard` owns under `newer` but not under `self` — the slots
    /// whose state must be installed at `shard` during the migration to
    /// `newer`.
    pub fn gains(&self, newer: &Placement, shard: u32) -> Vec<usize> {
        (0..SLOTS)
            .filter(|&s| newer.slots[s] == shard && self.slots[s] != shard)
            .collect()
    }

    /// Wire encoding: `epoch u64, n_shards u32, n_slots u32, slots × u32`
    /// (little-endian, appended to `buf`).
    pub fn encode(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(&self.epoch.to_le_bytes());
        buf.extend_from_slice(&self.n_shards.to_le_bytes());
        buf.extend_from_slice(&(self.slots.len() as u32).to_le_bytes());
        for &s in &self.slots {
            buf.extend_from_slice(&s.to_le_bytes());
        }
    }

    /// Wire decoding, validating the invariants (the wire is a trust
    /// boundary: a malformed table would silently fragment the view).
    pub fn decode(c: &mut Cursor) -> Result<Placement> {
        let epoch = c.u64()?;
        let n_shards = c.u32()?;
        let n_slots = c.u32()? as usize;
        if n_shards == 0 {
            bail!("placement with zero shards");
        }
        if n_slots != SLOTS {
            bail!("placement has {n_slots} slots, expected {SLOTS}");
        }
        let mut slots = Vec::with_capacity(SLOTS);
        for _ in 0..n_slots {
            let s = c.u32()?;
            if s >= n_shards {
                bail!("placement slot maps to shard {s} of {n_shards}");
            }
            slots.push(s);
        }
        Ok(Placement { epoch, n_shards, slots })
    }
}

/// max/mean ratio of a per-shard load vector — the skew number the
/// rebalancer triggers on and the fig7 rebalance sweep reports. 1.0 is
/// perfectly balanced; an all-zero window reports 1.0 (nothing to fix).
pub fn load_ratio(per_shard: &[u64]) -> f64 {
    if per_shard.is_empty() {
        return 1.0;
    }
    let total: u64 = per_shard.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / per_shard.len() as f64;
    let max = *per_shard.iter().max().expect("non-empty") as f64;
    max / mean
}

/// Plan slot moves that reduce `max/mean` per-shard load below
/// `max_ratio`, greedily: repeatedly move the hottest movable slot from
/// the most- to the least-loaded shard while that strictly lowers the
/// pairwise imbalance. `slot_loads[s]` is the observed load of slot `s`
/// over the measurement window. Returns an empty plan when the window is
/// already balanced (or nothing can improve — e.g. one slot carries all
/// the load).
pub fn plan_moves(
    placement: &Placement,
    slot_loads: &[u64],
    max_ratio: f64,
) -> Vec<(usize, u32)> {
    assert_eq!(slot_loads.len(), SLOTS, "one load per slot");
    let n = placement.n_shards();
    let mut owner: Vec<u32> = (0..SLOTS).map(|s| placement.shard_of_slot(s) as u32).collect();
    let mut shard_load = vec![0u64; n];
    for s in 0..SLOTS {
        shard_load[owner[s] as usize] += slot_loads[s];
    }
    let mut moves: Vec<(usize, u32)> = Vec::new();
    // Each iteration strictly reduces max-min imbalance, so SLOTS
    // iterations is a generous bound.
    for _ in 0..SLOTS {
        if load_ratio(&shard_load) <= max_ratio {
            break;
        }
        let (src, &src_load) =
            shard_load.iter().enumerate().max_by_key(|&(_, &l)| l).expect("shards");
        let (dst, &dst_load) =
            shard_load.iter().enumerate().min_by_key(|&(_, &l)| l).expect("shards");
        // Hottest slot on the source that still improves when moved:
        // after the move the pair is (src-l, dst+l); require dst+l <
        // src so the maximum of the pair strictly drops.
        let candidate = (0..SLOTS)
            .filter(|&s| owner[s] as usize == src && slot_loads[s] > 0)
            .filter(|&s| dst_load + slot_loads[s] < src_load)
            .max_by_key(|&s| slot_loads[s]);
        let Some(slot) = candidate else { break };
        owner[slot] = dst as u32;
        shard_load[src] -= slot_loads[slot];
        shard_load[dst] += slot_loads[slot];
        moves.push((slot, dst as u32));
    }
    moves
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::wire::Cursor;

    #[test]
    fn epoch0_matches_default_shard_of() {
        for n in [1usize, 2, 4, 7, 16] {
            let p = Placement::new(n);
            assert_eq!(p.epoch(), 0);
            assert_eq!(p.n_shards(), n);
            for app in 0..3u32 {
                for id in 0..300u32 {
                    assert_eq!(p.shard_of(app, id), Placement::default_shard_of(app, id, n));
                    assert!(p.shard_of(app, id) < n);
                }
            }
        }
    }

    #[test]
    fn moves_bump_epoch_and_reroute() {
        let p = Placement::new(4);
        let slot = Placement::slot_of(0, 7);
        let new_shard = ((p.shard_of_slot(slot) + 1) % 4) as u32;
        let q = p.with_moves(&[(slot, new_shard)]).unwrap();
        assert_eq!(q.epoch(), 1);
        assert_eq!(q.shard_of(0, 7), new_shard as usize);
        // Other slots untouched.
        for s in 0..SLOTS {
            if s != slot {
                assert_eq!(q.shard_of_slot(s), p.shard_of_slot(s));
            }
        }
        // Gains are visible from the diff.
        assert_eq!(p.gains(&q, new_shard), vec![slot]);
        assert!(p.gains(&q, p.shard_of_slot(slot) as u32).is_empty());
        // No-op and out-of-range plans are rejected.
        assert!(p.with_moves(&[(slot, p.shard_of_slot(slot) as u32)]).is_err());
        assert!(p.with_moves(&[(SLOTS, 0)]).is_err());
        assert!(p.with_moves(&[(0, 4)]).is_err());
    }

    #[test]
    fn wire_round_trip() {
        let p = Placement::new(7).with_moves(&[(3, 5), (250, 1)]).unwrap();
        let mut buf = Vec::new();
        p.encode(&mut buf);
        let q = Placement::decode(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(p, q);
        // Truncated/corrupt tables are refused.
        assert!(Placement::decode(&mut Cursor::new(&buf[..8])).is_err());
        let mut bad = Vec::new();
        Placement::new(2).encode(&mut bad);
        bad[8] = 1; // n_shards = 1, but slots reference shard 1
        assert!(Placement::decode(&mut Cursor::new(&bad)).is_err());
    }

    #[test]
    fn planner_fixes_single_hot_slot_skew() {
        let p = Placement::new(4);
        let mut loads = vec![10u64; SLOTS];
        // One slot carries ~30% of the total load.
        let hot = 17usize;
        loads[hot] = ((SLOTS as u64 - 1) * 10) * 3 / 7;
        let mut shard_load = vec![0u64; 4];
        for s in 0..SLOTS {
            shard_load[p.shard_of_slot(s)] += loads[s];
        }
        assert!(load_ratio(&shard_load) > 1.5, "setup must be skewed");
        let moves = plan_moves(&p, &loads, 1.2);
        assert!(!moves.is_empty());
        let q = p.with_moves(&moves).unwrap();
        let mut after = vec![0u64; 4];
        for s in 0..SLOTS {
            after[q.shard_of_slot(s)] += loads[s];
        }
        assert!(
            load_ratio(&after) < 1.5,
            "planned ratio {} must be under 1.5 (loads {after:?})",
            load_ratio(&after)
        );
    }

    #[test]
    fn planner_is_a_noop_when_balanced() {
        let p = Placement::new(4);
        let loads = vec![5u64; SLOTS];
        assert!(plan_moves(&p, &loads, 1.5).is_empty());
        // All-zero window: nothing to do.
        assert!(plan_moves(&p, &vec![0u64; SLOTS], 1.5).is_empty());
    }
}
