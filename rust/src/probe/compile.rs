//! AST → bytecode lowering. The site's `app`/`func` filters are folded
//! into the program as leading conjuncts, so a compiled probe is a
//! single predicate evaluation per record — one [`vm::eval`](super::vm)
//! call decides site *and* predicate with zero decoding.
//!
//! Every compiled program is run through the verifier before it is
//! returned, so the compiler cannot emit anything the wire would reject.

use anyhow::{bail, ensure, Result};

use super::bytecode::*;
use super::lang::{BinOp, Expr, ProbeDef};

struct Compiler {
    prog: Program,
}

impl Compiler {
    fn konst(&mut self, c: Const) -> Result<u16> {
        // Pool dedup keeps repeated literals within MAX_CONSTS. NaN floats
        // never compare equal, so they always append — harmless, a source
        // can't spell NaN anyway.
        if let Some(i) = self.prog.consts.iter().position(|x| x == &c) {
            return Ok(i as u16);
        }
        ensure!(
            self.prog.consts.len() < MAX_CONSTS,
            "predicate needs more than {MAX_CONSTS} constants"
        );
        if let Const::S(s) = &c {
            ensure!(s.len() <= MAX_STR, "string literal too long ({} > {MAX_STR})", s.len());
        }
        self.prog.consts.push(c);
        Ok((self.prog.consts.len() - 1) as u16)
    }

    fn emit(&mut self, op: u8) {
        self.prog.code.push(op);
    }

    fn emit_const(&mut self, c: Const) -> Result<()> {
        let i = self.konst(c)?;
        self.emit(OP_CONST);
        self.prog.code.extend_from_slice(&i.to_le_bytes());
        Ok(())
    }

    fn emit_streq(&mut self, field: u8, s: &str) -> Result<()> {
        let i = self.konst(Const::S(s.to_string()))?;
        self.emit(OP_STREQ);
        self.prog.code.push(field);
        self.prog.code.extend_from_slice(&i.to_le_bytes());
        Ok(())
    }

    fn expr(&mut self, e: &Expr) -> Result<()> {
        match e {
            Expr::Int(v) => self.emit_const(Const::U(*v))?,
            Expr::Float(v) => self.emit_const(Const::F(*v))?,
            Expr::Str(_) => {
                bail!("string literal is only valid compared (==/!=) against label or func")
            }
            Expr::Field(f) if *f == FIELD_LABEL || *f == FIELD_FUNC => {
                bail!(
                    "'{}' is a string field: compare it with ==/!= against a string",
                    field_name(*f).unwrap()
                )
            }
            Expr::Field(f) => {
                self.emit(OP_LOAD);
                self.prog.code.push(*f);
            }
            Expr::Not(x) => {
                self.expr(x)?;
                self.emit(OP_NOT);
            }
            Expr::Neg(x) => {
                self.emit_const(Const::F(0.0))?;
                self.expr(x)?;
                self.emit(OP_SUB);
            }
            Expr::Bin(op @ (BinOp::Eq | BinOp::Ne), a, b) => {
                // String comparisons lower to STREQ (+ NOT for !=); the
                // string may be on either side.
                let str_cmp = match (a.as_ref(), b.as_ref()) {
                    (Expr::Field(f), Expr::Str(s)) | (Expr::Str(s), Expr::Field(f))
                        if *f == FIELD_LABEL || *f == FIELD_FUNC =>
                    {
                        Some((*f, s.clone()))
                    }
                    _ => None,
                };
                match str_cmp {
                    Some((f, s)) => self.emit_streq(f, &s)?,
                    None => {
                        self.expr(a)?;
                        self.expr(b)?;
                        self.emit(OP_EQ);
                    }
                }
                if *op == BinOp::Ne {
                    // != is NOT of the equality. On the numeric path this
                    // is IEEE-correct: EQ(NaN,·) is false, so NE is true.
                    self.emit(OP_NOT);
                }
            }
            Expr::Bin(op, a, b) => {
                self.expr(a)?;
                self.expr(b)?;
                self.emit(match op {
                    BinOp::Lt => OP_LT,
                    BinOp::Le => OP_LE,
                    BinOp::Gt => OP_GT,
                    BinOp::Ge => OP_GE,
                    BinOp::And => OP_AND,
                    BinOp::Or => OP_OR,
                    BinOp::Add => OP_ADD,
                    BinOp::Sub => OP_SUB,
                    BinOp::Mul => OP_MUL,
                    BinOp::Div => OP_DIV,
                    BinOp::Eq | BinOp::Ne => unreachable!("handled above"),
                });
            }
        }
        Ok(())
    }
}

/// Compile one parsed probe to a verified [`Program`].
pub fn compile(def: &ProbeDef) -> Result<Program> {
    let mut c = Compiler { prog: Program::default() };
    let mut terms = 0usize;
    if let Some(app) = def.site.app {
        c.emit(OP_LOAD);
        c.prog.code.push(FIELD_APP);
        c.emit_const(Const::U(app as u64))?;
        c.emit(OP_EQ);
        terms += 1;
    }
    if let Some(f) = &def.site.func {
        c.emit_streq(FIELD_FUNC, f)?;
        terms += 1;
    }
    if let Some(p) = &def.pred {
        c.expr(p)?;
        terms += 1;
    }
    if terms == 0 {
        // Vacuously-true probe (pure wildcard site): 0 == 0.
        c.emit_const(Const::U(0))?;
        c.emit_const(Const::U(0))?;
        c.emit(OP_EQ);
        terms = 1;
    }
    for _ in 1..terms {
        c.emit(OP_AND);
    }
    c.emit(OP_RET);
    c.prog
        .verify()
        .map_err(|e| anyhow::anyhow!("probe predicate does not type-check: {e}"))?;
    Ok(c.prog)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::probe::lang::parse_one;
    use crate::probe::vm::eval;
    use crate::provenance::{codec, ProvRecord};

    fn enc(app: u32, func: &str, label: &str, score: f64, step: u64) -> Vec<u8> {
        let r = ProvRecord {
            call_id: 0,
            app,
            rank: 1,
            thread: 0,
            fid: 2,
            func: func.into(),
            step,
            entry_us: 10,
            exit_us: 20,
            inclusive_us: 10,
            exclusive_us: 5,
            depth: 0,
            parent: None,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: label.into(),
            score,
        };
        let mut b = Vec::new();
        codec::encode(&r, &mut b);
        b
    }

    fn compiled(src: &str) -> Program {
        compile(&parse_one(src).unwrap()).unwrap()
    }

    #[test]
    fn site_filters_fold_into_the_program() {
        let p = compiled("fn:3.md_force:exit / score > 1.0 /");
        assert!(eval(&p, &enc(3, "md_force", "normal", 2.0, 0)));
        assert!(!eval(&p, &enc(4, "md_force", "normal", 2.0, 0)), "app filter");
        assert!(!eval(&p, &enc(3, "md_io", "normal", 2.0, 0)), "func filter");
        assert!(!eval(&p, &enc(3, "md_force", "normal", 0.5, 0)), "predicate");
    }

    #[test]
    fn wildcard_site_is_vacuously_true() {
        let p = compiled("fn:*.*:exit");
        assert!(eval(&p, &enc(0, "anything", "normal", 0.0, 0)));
    }

    #[test]
    fn label_and_func_string_compares() {
        let p = compiled("fn:*.*:exit / label == \"weird\" /");
        assert!(eval(&p, &enc(0, "f", "weird", 0.0, 0)));
        assert!(!eval(&p, &enc(0, "f", "normal", 0.0, 0)));
        let p = compiled("fn:*.*:exit / label != \"normal\" && func == \"f\" /");
        assert!(eval(&p, &enc(0, "f", "anomaly_high", 0.0, 0)));
        assert!(!eval(&p, &enc(0, "f", "normal", 0.0, 0)));
        assert!(!eval(&p, &enc(0, "g", "anomaly_high", 0.0, 0)));
        // Reversed operand order.
        let p = compiled("fn:*.*:exit / \"weird\" == label /");
        assert!(eval(&p, &enc(0, "f", "weird", 0.0, 0)));
    }

    #[test]
    fn arithmetic_logicals_and_negation() {
        let p = compiled("fn:*.*:exit / score * 2.0 >= 4.0 || (anomaly && step != 7) /");
        assert!(eval(&p, &enc(0, "f", "normal", 2.0, 7)));
        assert!(eval(&p, &enc(0, "f", "anomaly_low", 0.0, 8)));
        assert!(!eval(&p, &enc(0, "f", "anomaly_low", 0.0, 7)));
        let p = compiled("fn:*.*:exit / score >= -0.5 /");
        assert!(eval(&p, &enc(0, "f", "normal", 0.0, 0)));
        assert!(!eval(&p, &enc(0, "f", "normal", -1.0, 0)));
    }

    #[test]
    fn type_errors_surface_at_compile_time() {
        for bad in [
            "fn:*.*:exit / label /",
            "fn:*.*:exit / func > 1 /",
            "fn:*.*:exit / \"str\" /",
            "fn:*.*:exit / \"a\" == \"b\" /",
            "fn:*.*:exit / score == \"x\" /",
            "fn:*.*:exit / anomaly + 1 > 0 /",
            "fn:*.*:exit / (score > 1) > (score > 2) /",
            "fn:*.*:exit / step && anomaly /",
            "fn:*.*:exit / !score /",
        ] {
            let def = parse_one(bad).unwrap();
            assert!(compile(&def).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn pool_dedup_keeps_repeats_compact() {
        let p = compiled(
            "fn:*.*:exit / step == 5 || step == 5 || step == 5 || label == \"x\" || label == \"x\" /",
        );
        assert_eq!(p.consts.len(), 2);
    }
}
