//! Probe DSL + predicate VM (ROADMAP: compiled filters where the data
//! lives). A probe is a DTrace-style one-liner —
//!
//! ```text
//! probe hot: fn:0.md_force:exit / score > 0.9 / sample 1% { capture(record); }
//! ```
//!
//! — lexed and parsed by [`lang`], lowered by [`compile`] to a compact
//! branch-free bytecode ([`bytecode`]: opcode stream + typed constant
//! pool), and evaluated by a register-free stack VM ([`vm`]) directly
//! against the 49-byte binary record header at fixed offsets, with zero
//! decoding on non-matching records. A verifier
//! ([`bytecode::verify`]) type-checks untrusted programs against hard
//! caps before they ever run, so probes can be installed over the wire.
//!
//! Three surfaces consume compiled probes:
//!
//! * **server-side filtered subscriptions** — provDB protocol kinds
//!   install/remove/list probes on a running `provdb-server`; a probe
//!   query scans the shards with the probe and pushes only matching
//!   records to the client (`provdb::net`, `provdb::store`);
//! * **probe-gated sampling** — the driver's `ProvSink` evaluates a
//!   sampling probe on each kept record under heavy ingest
//!   (`coordinator::driver`);
//! * **aggregator triggers** — the PS aggregator evaluates trigger
//!   probes on newly detected global events and pushes matching
//!   synthetic records straight into provDB, without waiting a sync
//!   period for every rank's dump (`ps::shard`).
//!
//! `rust/docs/probe.md` documents the grammar, opcode table, verifier
//! limits, and wire kinds.

pub mod bytecode;
mod compile;
pub mod lang;
pub mod vm;

pub use bytecode::{Const, Program};
pub use compile::compile;
pub use lang::{parse_one, parse_program, Action, Event, ProbeDef, Site, MAX_NAME, MAX_SOURCE};

use crate::util::wire::{put_str, Cursor};
use anyhow::{bail, ensure, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Probe wire-format version (independent of the record codec version).
pub const PROBE_WIRE_VERSION: u8 = 1;

/// Installed-probe cap per table (per provDB server).
pub const MAX_INSTALLED: usize = 64;

/// A named, compiled probe: everything a server needs to evaluate it
/// plus the original source for listings.
#[derive(Clone, Debug, PartialEq)]
pub struct Probe {
    pub name: String,
    /// Original source text (display/listing; not re-parsed).
    pub source: String,
    pub event: Event,
    /// Keep `n` of every `m` matching records (`None` keeps all).
    pub sample: Option<(u32, u32)>,
    pub action: Action,
    pub program: Program,
}

impl Probe {
    /// Compile exactly one probe from source. Unnamed probes get `p0`.
    pub fn compile(source: &str) -> Result<Probe> {
        let mut all = Self::compile_all(source)?;
        ensure!(all.len() == 1, "expected exactly one probe, found {}", all.len());
        Ok(all.pop().unwrap())
    }

    /// Compile every probe in `source`; unnamed probes are auto-named
    /// `p0`, `p1`, … by position. Duplicate names are rejected.
    pub fn compile_all(source: &str) -> Result<Vec<Probe>> {
        let defs = parse_program(source)?;
        let mut out = Vec::with_capacity(defs.len());
        let mut seen = std::collections::BTreeSet::new();
        for (i, def) in defs.iter().enumerate() {
            let program = compile(def)?;
            let name = def.name.clone().unwrap_or_else(|| format!("p{i}"));
            ensure!(seen.insert(name.clone()), "duplicate probe name '{name}'");
            let action = match def.actions.as_slice() {
                [] => Action::CaptureRecord,
                acts => {
                    ensure!(acts.len() == 1, "probe '{name}': one action per probe for now");
                    acts[0]
                }
            };
            out.push(Probe {
                name,
                source: source[def.span.0..def.span.1].trim().to_string(),
                event: def.site.event,
                sample: def.sample,
                action,
                program,
            });
        }
        Ok(out)
    }

    /// Evaluate the compiled predicate against an encoded record.
    pub fn matches(&self, rec: &[u8]) -> bool {
        vm::eval(&self.program, rec)
    }

    /// Sampling decision for the `counter`-th matching record (0-based):
    /// keep `n` of every `m`. Probes without a sample clause keep all.
    pub fn sample_keep(&self, counter: u64) -> bool {
        match self.sample {
            None => true,
            Some((n, m)) => counter % (m as u64) < n as u64,
        }
    }

    /// One-line summary for listings (`probe check`, `/api/probes`).
    pub fn describe(&self) -> String {
        format!(
            "{}: {} event={} sample={} code={}B consts={}",
            self.name,
            self.action.name(),
            self.event.name(),
            match self.sample {
                None => "all".to_string(),
                Some((n, m)) => format!("{n}/{m}"),
            },
            self.program.code.len(),
            self.program.consts.len(),
        )
    }

    /// Append the versioned wire encoding.
    pub fn to_wire(&self, out: &mut Vec<u8>) {
        out.push(PROBE_WIRE_VERSION);
        put_str(out, &self.name);
        out.push(match self.event {
            Event::Entry => 0,
            Event::Exit => 1,
        });
        match self.sample {
            None => out.push(0),
            Some((n, m)) => {
                out.push(1);
                out.extend_from_slice(&n.to_le_bytes());
                out.extend_from_slice(&m.to_le_bytes());
            }
        }
        out.push(match self.action {
            Action::CaptureRecord => 0,
            Action::CaptureStack => 1,
        });
        put_str(out, &self.source);
        out.extend_from_slice(&(self.program.consts.len() as u16).to_le_bytes());
        for c in &self.program.consts {
            match c {
                Const::U(v) => {
                    out.push(0);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                Const::F(v) => {
                    out.push(1);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                Const::S(s) => {
                    out.push(2);
                    put_str(out, s);
                }
            }
        }
        out.extend_from_slice(&(self.program.code.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.program.code);
    }

    /// Decode an untrusted wire probe: every cap is validated and the
    /// program is run through the verifier before it is returned.
    pub fn from_wire(cur: &mut Cursor) -> Result<Probe> {
        let ver = cur.u8()?;
        ensure!(ver == PROBE_WIRE_VERSION, "unsupported probe wire version {ver}");
        let name = cur.str()?;
        ensure!(!name.is_empty() && name.len() <= MAX_NAME, "bad probe name length {}", name.len());
        let event = match cur.u8()? {
            0 => Event::Entry,
            1 => Event::Exit,
            other => bail!("bad probe event tag {other}"),
        };
        let sample = match cur.u8()? {
            0 => None,
            1 => {
                let n = cur.u32()?;
                let m = cur.u32()?;
                ensure!(m > 0 && m <= 1_000_000 && n <= m, "bad sample rate {n}/{m}");
                Some((n, m))
            }
            other => bail!("bad sample tag {other}"),
        };
        let action = match cur.u8()? {
            0 => Action::CaptureRecord,
            1 => Action::CaptureStack,
            other => bail!("bad probe action tag {other}"),
        };
        let source = cur.str()?;
        ensure!(source.len() <= MAX_SOURCE, "probe source too long");
        let n_consts = cur.u16()? as usize;
        ensure!(n_consts <= bytecode::MAX_CONSTS, "too many constants ({n_consts})");
        let mut consts = Vec::with_capacity(n_consts);
        for _ in 0..n_consts {
            consts.push(match cur.u8()? {
                0 => Const::U(cur.u64()?),
                1 => Const::F(cur.f64()?),
                2 => {
                    let s = cur.str()?;
                    ensure!(s.len() <= bytecode::MAX_STR, "pool string too long");
                    Const::S(s)
                }
                other => bail!("bad constant tag {other}"),
            });
        }
        let code_len = cur.u32()? as usize;
        ensure!(code_len <= bytecode::MAX_CODE, "code too long ({code_len})");
        let code = cur.take_slice(code_len)?.to_vec();
        let program = Program { consts, code };
        program.verify()?;
        Ok(Probe { name, source, event, sample, action, program })
    }
}

/// A probe installed on a server, with live counters. `matches` counts
/// predicate hits, `shed` the hits dropped by the sampling gate,
/// `pushed_records`/`pushed_bytes` what actually crossed the wire to
/// subscribers — together they prove non-matching records never left
/// the server.
pub struct InstalledProbe {
    pub probe: Probe,
    pub matches: AtomicU64,
    pub shed: AtomicU64,
    pub pushed_records: AtomicU64,
    pub pushed_bytes: AtomicU64,
    counter: AtomicU64,
}

impl InstalledProbe {
    pub fn new(probe: Probe) -> InstalledProbe {
        InstalledProbe {
            probe,
            matches: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            pushed_records: AtomicU64::new(0),
            pushed_bytes: AtomicU64::new(0),
            counter: AtomicU64::new(0),
        }
    }

    /// Predicate + sampling gate against one encoded record, bumping the
    /// match/shed counters. `true` means the record should reach the
    /// subscriber.
    pub fn admit(&self, rec: &[u8]) -> bool {
        if !self.probe.matches(rec) {
            return false;
        }
        self.matches.fetch_add(1, Ordering::Relaxed);
        let c = self.counter.fetch_add(1, Ordering::Relaxed);
        if self.probe.sample_keep(c) {
            true
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
            false
        }
    }

    /// Account records that crossed the wire to a subscriber.
    pub fn note_pushed(&self, records: u64, bytes: u64) {
        self.pushed_records.fetch_add(records, Ordering::Relaxed);
        self.pushed_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
}

/// Server-side registry of installed probes, shared across connections.
#[derive(Default)]
pub struct ProbeTable {
    inner: RwLock<BTreeMap<String, Arc<InstalledProbe>>>,
}

impl ProbeTable {
    pub fn new() -> ProbeTable {
        ProbeTable::default()
    }

    /// Install (or replace) a probe by name. Fails when the table is
    /// full and the name is new (re-installs always succeed).
    pub fn install(&self, probe: Probe) -> Result<()> {
        let mut map = self.inner.write().expect("probe table poisoned");
        ensure!(
            map.len() < MAX_INSTALLED || map.contains_key(&probe.name),
            "probe table full ({MAX_INSTALLED} installed)"
        );
        map.insert(probe.name.clone(), Arc::new(InstalledProbe::new(probe)));
        Ok(())
    }

    /// Remove by name; `true` when it existed.
    pub fn remove(&self, name: &str) -> bool {
        self.inner.write().expect("probe table poisoned").remove(name).is_some()
    }

    pub fn get(&self, name: &str) -> Option<Arc<InstalledProbe>> {
        self.inner.read().expect("probe table poisoned").get(name).cloned()
    }

    /// All installed probes, name-ordered.
    pub fn list(&self) -> Vec<Arc<InstalledProbe>> {
        self.inner.read().expect("probe table poisoned").values().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.inner.read().expect("probe table poisoned").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe(src: &str) -> Probe {
        Probe::compile(src).unwrap()
    }

    #[test]
    fn wire_round_trip_preserves_everything() {
        for src in [
            "fn:*.*:exit",
            "probe hot: fn:0.md_force:exit / score > 0.9 / sample 1% { capture(stack); }",
            "fn:2.\"q f\":entry / label == \"ünï\" && step >= 18446744073709551615 / sample 3/7",
        ] {
            let p = probe(src);
            let mut buf = Vec::new();
            p.to_wire(&mut buf);
            let q = Probe::from_wire(&mut Cursor::new(&buf)).unwrap();
            assert_eq!(p, q, "round trip of {src}");
        }
    }

    #[test]
    fn wire_decode_rejects_mutations_without_panicking() {
        let p = probe("probe hot: fn:0.md_force:exit / score > 0.9 / sample 1%");
        let mut buf = Vec::new();
        p.to_wire(&mut buf);
        // Truncations at every length.
        for n in 0..buf.len() {
            let _ = Probe::from_wire(&mut Cursor::new(&buf[..n]));
        }
        // Single-byte mutations: must decode identical, reject, or at
        // worst produce a different-but-verified program — never panic.
        for i in 0..buf.len() {
            let mut m = buf.clone();
            m[i] ^= 0xA5;
            if let Ok(q) = Probe::from_wire(&mut Cursor::new(&m)) {
                q.program.verify().unwrap();
            }
        }
    }

    #[test]
    fn compile_all_names_and_spans() {
        let src = "fn:*.*:exit\nprobe named: fn:1.f:entry / anomaly /\nfn:*.*:exit sample 50%";
        let all = Probe::compile_all(src).unwrap();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].name, "p0");
        assert_eq!(all[1].name, "named");
        assert_eq!(all[2].name, "p2");
        assert!(all[1].source.starts_with("probe named:"));
        assert_eq!(all[2].sample, Some((50, 100)));
        // Duplicate names rejected.
        assert!(Probe::compile_all("probe x: fn:*.*:exit\nprobe x: fn:*.*:exit").is_err());
    }

    #[test]
    fn sampling_keeps_n_of_m() {
        let p = probe("fn:*.*:exit sample 1%");
        let kept = (0..1000).filter(|&c| p.sample_keep(c)).count();
        assert_eq!(kept, 10);
        let p = probe("fn:*.*:exit sample 3/7");
        let kept = (0..700).filter(|&c| p.sample_keep(c)).count();
        assert_eq!(kept, 300);
        let p = probe("fn:*.*:exit");
        assert!((0..100).all(|c| p.sample_keep(c)));
        // 0/m sheds everything.
        let p = probe("fn:*.*:exit sample 0/4");
        assert!(!(0..100).any(|c| p.sample_keep(c)));
    }

    #[test]
    fn installed_probe_counters() {
        let mut buf = Vec::new();
        crate::provenance::codec::encode(
            &crate::provenance::ProvRecord {
                call_id: 0,
                app: 0,
                rank: 0,
                thread: 0,
                fid: 0,
                func: "f".into(),
                step: 0,
                entry_us: 0,
                exit_us: 0,
                inclusive_us: 0,
                exclusive_us: 0,
                depth: 0,
                parent: None,
                n_children: 0,
                n_messages: 0,
                msg_bytes: 0,
                label: "anomaly_high".into(),
                score: 5.0,
            },
            &mut buf,
        );
        let ip = InstalledProbe::new(probe("fn:*.*:exit / anomaly / sample 1/2"));
        let admitted = (0..10).filter(|_| ip.admit(&buf)).count();
        assert_eq!(admitted, 5);
        assert_eq!(ip.matches.load(Ordering::Relaxed), 10);
        assert_eq!(ip.shed.load(Ordering::Relaxed), 5);
        // Non-matching records bump nothing.
        let ip2 = InstalledProbe::new(probe("fn:9.f:exit"));
        assert!(!ip2.admit(&buf));
        assert_eq!(ip2.matches.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn probe_table_install_remove_list_caps() {
        let t = ProbeTable::new();
        for i in 0..MAX_INSTALLED {
            t.install(Probe {
                name: format!("n{i}"),
                ..probe("fn:*.*:exit")
            })
            .unwrap();
        }
        assert_eq!(t.len(), MAX_INSTALLED);
        // Full: new name rejected, re-install of existing allowed.
        assert!(t.install(Probe { name: "overflow".into(), ..probe("fn:*.*:exit") }).is_err());
        t.install(Probe { name: "n0".into(), ..probe("fn:*.*:exit sample 1%") }).unwrap();
        assert_eq!(t.get("n0").unwrap().probe.sample, Some((1, 100)));
        assert!(t.remove("n1"));
        assert!(!t.remove("n1"));
        assert_eq!(t.len(), MAX_INSTALLED - 1);
        assert_eq!(t.list().len(), MAX_INSTALLED - 1);
    }
}
