//! Probe bytecode: opcodes, typed constant pool, and the verifier that
//! makes over-the-wire programs safe to run against every scanned record.
//!
//! The instruction set is deliberately *branch-free*: a verified program
//! is a straight-line expression evaluation ending in [`OP_RET`], so the
//! per-record instruction budget is simply the code length (≤
//! [`MAX_CODE`]) — no jump targets to validate, no loop bounds to prove.
//! The verifier statically simulates the operand stack with abstract
//! types, so the VM ([`super::vm`]) never sees a type confusion, a stack
//! underflow, an out-of-range constant index, or a string where a number
//! is expected.
//!
//! Untrusted programs (probe installs arriving over the provDB wire) are
//! run through [`verify`] before they are ever evaluated; rejection is an
//! `Err`, never a panic (pinned by the fuzz tests in `tests/probe.rs`).

use anyhow::{bail, ensure, Result};

// ---- opcodes -------------------------------------------------------------

/// Return the boolean at the top of the stack. Must be the final byte of
/// the program (the verifier enforces exactly one `RET`, at the end).
pub const OP_RET: u8 = 0;
/// `CONST <u16 idx>` — push constant pool entry `idx` (numeric only;
/// strings are operands of [`OP_STREQ`], never stack values).
pub const OP_CONST: u8 = 1;
/// `LOAD <u8 field>` — push a header field read at its fixed offset.
pub const OP_LOAD: u8 = 2;
/// `STREQ <u8 field> <u16 idx>` — push `record.field == consts[idx]` for
/// the string fields ([`FIELD_LABEL`], [`FIELD_FUNC`]). The comparison
/// walks the encoded payload at fixed offsets; it never decodes.
pub const OP_STREQ: u8 = 3;
pub const OP_EQ: u8 = 4;
pub const OP_NE: u8 = 5;
pub const OP_LT: u8 = 6;
pub const OP_LE: u8 = 7;
pub const OP_GT: u8 = 8;
pub const OP_GE: u8 = 9;
pub const OP_AND: u8 = 10;
pub const OP_OR: u8 = 11;
pub const OP_NOT: u8 = 12;
pub const OP_ADD: u8 = 13;
pub const OP_SUB: u8 = 14;
pub const OP_MUL: u8 = 15;
pub const OP_DIV: u8 = 16;

// ---- record fields (operands of LOAD / STREQ) ----------------------------

pub const FIELD_APP: u8 = 0;
pub const FIELD_RANK: u8 = 1;
pub const FIELD_FID: u8 = 2;
pub const FIELD_STEP: u8 = 3;
pub const FIELD_ENTRY_US: u8 = 4;
pub const FIELD_EXIT_US: u8 = 5;
pub const FIELD_SCORE: u8 = 6;
/// `label != "normal"` as a single header-byte read (`Bool`).
pub const FIELD_ANOMALY: u8 = 7;
/// String field: the record label (header tag, or the payload text for
/// custom labels). STREQ-only.
pub const FIELD_LABEL: u8 = 8;
/// String field: the function name in the payload. STREQ-only.
pub const FIELD_FUNC: u8 = 9;

/// Source-language name of a field id (diagnostics, docs).
pub fn field_name(f: u8) -> Option<&'static str> {
    Some(match f {
        FIELD_APP => "app",
        FIELD_RANK => "rank",
        FIELD_FID => "fid",
        FIELD_STEP => "step",
        FIELD_ENTRY_US => "entry_us",
        FIELD_EXIT_US => "exit_us",
        FIELD_SCORE => "score",
        FIELD_ANOMALY => "anomaly",
        FIELD_LABEL => "label",
        FIELD_FUNC => "func",
        _ => return None,
    })
}

/// Field id of a source-language name.
pub fn field_of_name(s: &str) -> Option<u8> {
    Some(match s {
        "app" => FIELD_APP,
        "rank" => FIELD_RANK,
        "fid" => FIELD_FID,
        "step" => FIELD_STEP,
        "entry_us" => FIELD_ENTRY_US,
        "exit_us" => FIELD_EXIT_US,
        "score" => FIELD_SCORE,
        "anomaly" => FIELD_ANOMALY,
        "label" => FIELD_LABEL,
        "func" => FIELD_FUNC,
        _ => return None,
    })
}

// ---- verifier limits -----------------------------------------------------

/// Hard per-record instruction budget: code longer than this is rejected
/// at install time, and the VM re-enforces it as defense in depth.
pub const MAX_CODE: usize = 1024;
/// Constant-pool cap.
pub const MAX_CONSTS: usize = 64;
/// Pool-string byte cap.
pub const MAX_STR: usize = 256;
/// Operand-stack depth cap (abstractly checked here, concretely in the VM).
pub const MAX_STACK: usize = 32;

/// A typed constant-pool entry. Integers and floats are distinct so u64
/// comparisons stay exact above 2^53 (`step`, timestamps) — they only
/// coerce to f64 when mixed with a float operand.
#[derive(Clone, Debug, PartialEq)]
pub enum Const {
    U(u64),
    F(f64),
    S(String),
}

/// A compiled probe predicate: opcode stream + constant pool.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Program {
    pub consts: Vec<Const>,
    pub code: Vec<u8>,
}

impl Program {
    /// Convenience wrapper over [`verify`].
    pub fn verify(&self) -> Result<()> {
        verify(self)
    }
}

/// Abstract operand type for static stack simulation.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
enum Ty {
    U,
    F,
    B,
}

/// Validate an untrusted program: bounded pool and code, in-range
/// operands, and a full abstract-typed stack simulation — every pop is
/// type-checked, depth never exceeds [`MAX_STACK`], and the single
/// [`OP_RET`] (which must be the last byte) returns exactly one `Bool`.
pub fn verify(p: &Program) -> Result<()> {
    ensure!(!p.code.is_empty(), "empty program");
    ensure!(p.code.len() <= MAX_CODE, "code too long ({} > {MAX_CODE})", p.code.len());
    ensure!(
        p.consts.len() <= MAX_CONSTS,
        "constant pool too large ({} > {MAX_CONSTS})",
        p.consts.len()
    );
    for c in &p.consts {
        if let Const::S(s) = c {
            ensure!(s.len() <= MAX_STR, "pool string too long ({} > {MAX_STR})", s.len());
        }
    }
    fn take1(code: &[u8], pc: &mut usize, at: usize) -> Result<u8> {
        let v = *code
            .get(*pc)
            .ok_or_else(|| anyhow::anyhow!("truncated operand at pc {at}"))?;
        *pc += 1;
        Ok(v)
    }
    fn take2(code: &[u8], pc: &mut usize, at: usize) -> Result<u16> {
        let lo = take1(code, pc, at)?;
        let hi = take1(code, pc, at)?;
        Ok(u16::from_le_bytes([lo, hi]))
    }
    fn pop(stack: &mut Vec<Ty>, at: usize) -> Result<Ty> {
        stack.pop().ok_or_else(|| anyhow::anyhow!("stack underflow at pc {at}"))
    }
    let code = &p.code;
    let mut stack: Vec<Ty> = Vec::with_capacity(MAX_STACK);
    let mut pc = 0usize;
    while pc < code.len() {
        let at = pc;
        let op = code[pc];
        pc += 1;
        match op {
            OP_RET => {
                ensure!(pc == code.len(), "RET before end of code at pc {at}");
                ensure!(stack.len() == 1, "RET with stack depth {} at pc {at}", stack.len());
                ensure!(stack[0] == Ty::B, "RET with non-bool result at pc {at}");
                return Ok(());
            }
            OP_CONST => {
                let idx = take2(code, &mut pc, at)? as usize;
                match p.consts.get(idx) {
                    Some(Const::U(_)) => stack.push(Ty::U),
                    Some(Const::F(_)) => stack.push(Ty::F),
                    Some(Const::S(_)) => bail!("CONST of string pool entry {idx} at pc {at} (strings are STREQ operands)"),
                    None => bail!("CONST index {idx} out of range at pc {at}"),
                }
            }
            OP_LOAD => {
                let f = take1(code, &mut pc, at)?;
                match f {
                    FIELD_APP | FIELD_RANK | FIELD_FID | FIELD_STEP | FIELD_ENTRY_US
                    | FIELD_EXIT_US => stack.push(Ty::U),
                    FIELD_SCORE => stack.push(Ty::F),
                    FIELD_ANOMALY => stack.push(Ty::B),
                    FIELD_LABEL | FIELD_FUNC => {
                        bail!("LOAD of string field {} at pc {at} (use STREQ)", field_name(f).unwrap())
                    }
                    _ => bail!("LOAD of unknown field {f} at pc {at}"),
                }
            }
            OP_STREQ => {
                let f = take1(code, &mut pc, at)?;
                ensure!(
                    f == FIELD_LABEL || f == FIELD_FUNC,
                    "STREQ of non-string field {f} at pc {at}"
                );
                let idx = take2(code, &mut pc, at)? as usize;
                match p.consts.get(idx) {
                    Some(Const::S(_)) => stack.push(Ty::B),
                    Some(_) => bail!("STREQ against non-string pool entry {idx} at pc {at}"),
                    None => bail!("STREQ index {idx} out of range at pc {at}"),
                }
            }
            OP_EQ | OP_NE | OP_LT | OP_LE | OP_GT | OP_GE => {
                let b = pop(&mut stack, at)?;
                let a = pop(&mut stack, at)?;
                ensure!(
                    a != Ty::B && b != Ty::B,
                    "numeric comparison of bool operand at pc {at}"
                );
                stack.push(Ty::B);
            }
            OP_AND | OP_OR => {
                let b = pop(&mut stack, at)?;
                let a = pop(&mut stack, at)?;
                ensure!(a == Ty::B && b == Ty::B, "logical op on non-bool at pc {at}");
                stack.push(Ty::B);
            }
            OP_NOT => {
                let a = pop(&mut stack, at)?;
                ensure!(a == Ty::B, "NOT on non-bool at pc {at}");
                stack.push(Ty::B);
            }
            OP_ADD | OP_SUB | OP_MUL | OP_DIV => {
                let b = pop(&mut stack, at)?;
                let a = pop(&mut stack, at)?;
                ensure!(
                    a != Ty::B && b != Ty::B,
                    "arithmetic on bool operand at pc {at}"
                );
                // Arithmetic is evaluated in f64 regardless of input types.
                stack.push(Ty::F);
            }
            other => bail!("unknown opcode {other} at pc {at}"),
        }
        ensure!(stack.len() <= MAX_STACK, "stack depth exceeds {MAX_STACK} at pc {at}");
    }
    bail!("program does not end in RET")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn prog(consts: Vec<Const>, code: Vec<u8>) -> Program {
        Program { consts, code }
    }

    #[test]
    fn verifies_minimal_true_program() {
        // 0 == 0 → true
        let p = prog(
            vec![Const::U(0)],
            vec![OP_CONST, 0, 0, OP_CONST, 0, 0, OP_EQ, OP_RET],
        );
        p.verify().unwrap();
    }

    #[test]
    fn rejects_structural_garbage() {
        // Empty.
        assert!(verify(&prog(vec![], vec![])).is_err());
        // No RET.
        assert!(verify(&prog(vec![Const::U(1)], vec![OP_CONST, 0, 0])).is_err());
        // RET with non-bool.
        assert!(verify(&prog(vec![Const::U(1)], vec![OP_CONST, 0, 0, OP_RET])).is_err());
        // RET with empty stack.
        assert!(verify(&prog(vec![], vec![OP_RET])).is_err());
        // RET not last.
        let p = prog(
            vec![Const::U(0)],
            vec![OP_CONST, 0, 0, OP_CONST, 0, 0, OP_EQ, OP_RET, OP_NOT],
        );
        assert!(verify(&p).is_err());
        // Unknown opcode.
        assert!(verify(&prog(vec![], vec![99, OP_RET])).is_err());
        // Truncated operand.
        assert!(verify(&prog(vec![Const::U(0)], vec![OP_CONST, 0])).is_err());
    }

    #[test]
    fn rejects_type_confusion() {
        // Logical AND of numbers.
        let p = prog(
            vec![Const::U(1)],
            vec![OP_CONST, 0, 0, OP_CONST, 0, 0, OP_AND, OP_RET],
        );
        assert!(verify(&p).is_err());
        // Comparison of bools.
        let p = prog(
            vec![],
            vec![OP_LOAD, FIELD_ANOMALY, OP_LOAD, FIELD_ANOMALY, OP_LT, OP_RET],
        );
        assert!(verify(&p).is_err());
        // LOAD of a string field.
        assert!(verify(&prog(vec![], vec![OP_LOAD, FIELD_LABEL, OP_RET])).is_err());
        // CONST of a string.
        let p = prog(vec![Const::S("x".into())], vec![OP_CONST, 0, 0, OP_RET]);
        assert!(verify(&p).is_err());
        // STREQ against a number.
        let p = prog(vec![Const::U(1)], vec![OP_STREQ, FIELD_LABEL, 0, 0, OP_RET]);
        assert!(verify(&p).is_err());
        // STREQ of a numeric field.
        let p = prog(vec![Const::S("x".into())], vec![OP_STREQ, FIELD_SCORE, 0, 0, OP_RET]);
        assert!(verify(&p).is_err());
    }

    #[test]
    fn rejects_over_budget_programs() {
        // Code over MAX_CODE.
        let mut code = vec![OP_LOAD, FIELD_ANOMALY];
        while code.len() <= MAX_CODE {
            code.push(OP_NOT);
        }
        code.push(OP_RET);
        assert!(verify(&prog(vec![], code)).is_err());
        // Pool over MAX_CONSTS.
        let consts = vec![Const::U(1); MAX_CONSTS + 1];
        assert!(verify(&prog(consts, vec![OP_LOAD, FIELD_ANOMALY, OP_RET])).is_err());
        // String over MAX_STR.
        let consts = vec![Const::S("x".repeat(MAX_STR + 1))];
        let code = vec![OP_STREQ, FIELD_LABEL, 0, 0, OP_RET];
        assert!(verify(&prog(consts, code)).is_err());
        // Stack deeper than MAX_STACK.
        let mut code = Vec::new();
        for _ in 0..MAX_STACK + 1 {
            code.extend_from_slice(&[OP_LOAD, FIELD_ANOMALY]);
        }
        code.push(OP_RET);
        assert!(verify(&prog(vec![], code)).is_err());
    }

    #[test]
    fn field_names_round_trip() {
        for f in 0..=FIELD_FUNC {
            assert_eq!(field_of_name(field_name(f).unwrap()), Some(f));
        }
        assert_eq!(field_name(200), None);
        assert_eq!(field_of_name("bogus"), None);
    }
}
