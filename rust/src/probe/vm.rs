//! Register-free stack VM evaluating compiled probe predicates directly
//! against *encoded* provenance records — header fields at their fixed
//! [`codec`](crate::provenance::codec) offsets, and the two payload
//! strings (`func`, custom `label`) located by a fixed-offset walk. A
//! record that fails the predicate is never decoded.
//!
//! [`eval`] is total: any fault (type confusion, stack underflow, bad
//! opcode, truncated record) yields `false`, never a panic. Programs are
//! expected to be [`verify`](super::bytecode::verify)-checked first —
//! the fault paths here are defense in depth, and the instruction budget
//! is re-enforced at runtime so even an unverified program terminates
//! within [`MAX_CODE`] steps.

use super::bytecode::*;
use crate::provenance::codec::{self, HEADER_LEN, LABEL_NORMAL, LABEL_OTHER};

/// Runtime value. `U`/`F` are kept distinct so u64×u64 comparisons are
/// exact above 2^53 (step counters, microsecond timestamps) — mixed-type
/// comparisons and all arithmetic coerce to f64.
#[derive(Copy, Clone, Debug)]
enum Val {
    U(u64),
    F(f64),
    B(bool),
}

impl Val {
    fn as_f64(self) -> Option<f64> {
        match self {
            Val::U(u) => Some(u as f64),
            Val::F(f) => Some(f),
            Val::B(_) => None,
        }
    }
}

/// Evaluate `p` against an encoded record; any fault is `false`.
pub fn eval(p: &Program, rec: &[u8]) -> bool {
    eval_checked(p, rec).unwrap_or(false)
}

fn eval_checked(p: &Program, rec: &[u8]) -> Option<bool> {
    let code = &p.code;
    if code.len() > MAX_CODE {
        return None;
    }
    let mut stack: Vec<Val> = Vec::with_capacity(8);
    let mut pc = 0usize;
    while pc < code.len() {
        let op = code[pc];
        pc += 1;
        match op {
            OP_RET => {
                return match (stack.pop()?, stack.is_empty()) {
                    (Val::B(b), true) => Some(b),
                    _ => None,
                };
            }
            OP_CONST => {
                let idx = imm16(code, &mut pc)? as usize;
                match p.consts.get(idx)? {
                    Const::U(u) => stack.push(Val::U(*u)),
                    Const::F(f) => stack.push(Val::F(*f)),
                    Const::S(_) => return None,
                }
            }
            OP_LOAD => {
                let f = *code.get(pc)?;
                pc += 1;
                stack.push(load_field(rec, f)?);
            }
            OP_STREQ => {
                let f = *code.get(pc)?;
                pc += 1;
                let idx = imm16(code, &mut pc)? as usize;
                let Const::S(want) = p.consts.get(idx)? else { return None };
                let hit = match f {
                    FIELD_LABEL => label_eq(rec, want),
                    FIELD_FUNC => func_eq(rec, want),
                    _ => return None,
                };
                stack.push(Val::B(hit));
            }
            OP_EQ | OP_NE | OP_LT | OP_LE | OP_GT | OP_GE => {
                let b = stack.pop()?;
                let a = stack.pop()?;
                stack.push(Val::B(compare(op, a, b)?));
            }
            OP_AND | OP_OR => {
                let (Val::B(b), Val::B(a)) = (stack.pop()?, stack.pop()?) else {
                    return None;
                };
                stack.push(Val::B(if op == OP_AND { a && b } else { a || b }));
            }
            OP_NOT => {
                let Val::B(a) = stack.pop()? else { return None };
                stack.push(Val::B(!a));
            }
            OP_ADD | OP_SUB | OP_MUL | OP_DIV => {
                let b = stack.pop()?.as_f64()?;
                let a = stack.pop()?.as_f64()?;
                stack.push(Val::F(match op {
                    OP_ADD => a + b,
                    OP_SUB => a - b,
                    OP_MUL => a * b,
                    _ => a / b,
                }));
            }
            _ => return None,
        }
        if stack.len() > MAX_STACK {
            return None;
        }
    }
    None // fell off the end without RET
}

fn imm16(code: &[u8], pc: &mut usize) -> Option<u16> {
    let lo = *code.get(*pc)?;
    let hi = *code.get(*pc + 1)?;
    *pc += 2;
    Some(u16::from_le_bytes([lo, hi]))
}

/// Comparison semantics mirror [`ProvQuery::matches`]
/// (crate::provenance::ProvQuery): u64×u64 is an exact integer compare;
/// anything mixed goes through f64 with IEEE ordering, so `NaN` fails
/// every ordered comparison (and `EQ`), and satisfies `NE`.
fn compare(op: u8, a: Val, b: Val) -> Option<bool> {
    use std::cmp::Ordering::*;
    let ord = match (a, b) {
        (Val::U(x), Val::U(y)) => Some(x.cmp(&y)),
        (Val::B(_), _) | (_, Val::B(_)) => return None,
        (x, y) => x.as_f64()?.partial_cmp(&y.as_f64()?),
    };
    Some(match op {
        OP_EQ => ord == Some(Equal),
        OP_NE => ord != Some(Equal),
        OP_LT => ord == Some(Less),
        OP_LE => matches!(ord, Some(Less | Equal)),
        OP_GT => ord == Some(Greater),
        _ => matches!(ord, Some(Greater | Equal)),
    })
}

// ---- fixed-offset record access ------------------------------------------

fn u32le(buf: &[u8], off: usize) -> Option<u32> {
    Some(u32::from_le_bytes(buf.get(off..off + 4)?.try_into().ok()?))
}

fn u64le(buf: &[u8], off: usize) -> Option<u64> {
    Some(u64::from_le_bytes(buf.get(off..off + 8)?.try_into().ok()?))
}

fn load_field(rec: &[u8], f: u8) -> Option<Val> {
    Some(match f {
        FIELD_APP => Val::U(u32le(rec, 0)? as u64),
        FIELD_RANK => Val::U(u32le(rec, 4)? as u64),
        FIELD_FID => Val::U(u32le(rec, 8)? as u64),
        FIELD_STEP => Val::U(u64le(rec, 12)?),
        FIELD_ENTRY_US => Val::U(u64le(rec, 20)?),
        FIELD_EXIT_US => Val::U(u64le(rec, 28)?),
        FIELD_SCORE => Val::F(f64::from_bits(u64le(rec, 36)?)),
        FIELD_ANOMALY => Val::B(*rec.get(44)? != LABEL_NORMAL),
        _ => return None,
    })
}

/// The record's payload slice, bounded by the header's `payload_len`.
fn payload(rec: &[u8]) -> Option<&[u8]> {
    let plen = u32le(rec, 45)? as usize;
    rec.get(HEADER_LEN..HEADER_LEN.checked_add(plen)?)
}

/// Byte offset of the func length-prefix inside the payload. The prefix
/// fields are all fixed-width except the optional parent id, selected by
/// the tag byte at payload offset 32.
fn func_off(p: &[u8]) -> Option<usize> {
    // call_id u64 + thread u32 + inclusive u64 + exclusive u64 + depth u32
    // = 32 bytes, then the parent tag byte, then (maybe) parent u64, then
    // n_children u32 + n_messages u32 + msg_bytes u64 = 16 bytes.
    let base = match *p.get(32)? {
        0 => 33,
        1 => 41,
        _ => return None,
    };
    Some(base + 16)
}

/// The function-name bytes of an encoded record, without decoding it.
pub fn func_bytes(rec: &[u8]) -> Option<&[u8]> {
    let p = payload(rec)?;
    let off = func_off(p)?;
    let len = u32le(p, off)? as usize;
    let start = off.checked_add(4)?;
    p.get(start..start.checked_add(len)?)
}

/// The custom-label bytes of an encoded record whose header tag is
/// [`LABEL_OTHER`] (`None` for well-known tags or malformed payloads).
pub fn custom_label_bytes(rec: &[u8]) -> Option<&[u8]> {
    if *rec.get(44)? != LABEL_OTHER {
        return None;
    }
    let p = payload(rec)?;
    let foff = func_off(p)?;
    let flen = u32le(p, foff)? as usize;
    let loff = foff.checked_add(4)?.checked_add(flen)?;
    let len = u32le(p, loff)? as usize;
    let start = loff.checked_add(4)?;
    p.get(start..start.checked_add(len)?)
}

/// Compare the record's label against `want` without decoding: header
/// tag for well-known labels, payload text for custom ones. This is the
/// comparison that settles the one case
/// [`codec::matches_header`] cannot — a custom query label against a
/// custom record label (`None` from `matches_header`; the provDB scan
/// path routes it here instead of decoding the whole record).
pub fn label_eq(rec: &[u8], want: &str) -> bool {
    match rec.get(44) {
        Some(&tag) if tag != LABEL_OTHER => codec::label_of_tag(tag) == Some(want),
        Some(_) => custom_label_bytes(rec).is_some_and(|b| b == want.as_bytes()),
        None => false,
    }
}

/// Compare the record's function name against `want` without decoding.
pub fn func_eq(rec: &[u8], want: &str) -> bool {
    func_bytes(rec).is_some_and(|b| b == want.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::provenance::ProvRecord;

    pub(crate) fn rec(label: &str, func: &str, parent: Option<u64>) -> Vec<u8> {
        let r = ProvRecord {
            call_id: 7,
            app: 1,
            rank: 2,
            thread: 0,
            fid: 3,
            func: func.to_string(),
            step: 11,
            entry_us: 100,
            exit_us: 200,
            inclusive_us: 100,
            exclusive_us: 60,
            depth: 1,
            parent,
            n_children: 0,
            n_messages: 0,
            msg_bytes: 0,
            label: label.to_string(),
            score: 4.5,
        };
        let mut buf = Vec::new();
        codec::encode(&r, &mut buf);
        buf
    }

    #[test]
    fn fixed_offset_string_access_matches_decode() {
        for (label, parent) in [
            ("normal", None),
            ("anomaly_high", Some(42)),
            ("weird_label", None),
            ("ünïcode-étiquette", Some(1)),
        ] {
            let buf = rec(label, "md_force", parent);
            let (dec, _) = codec::decode(&buf).unwrap();
            assert_eq!(func_bytes(&buf).unwrap(), dec.func.as_bytes());
            assert!(func_eq(&buf, "md_force"));
            assert!(!func_eq(&buf, "md_forc"));
            assert!(label_eq(&buf, label), "label_eq({label})");
            assert!(!label_eq(&buf, "something_else"));
            if codec::label_tag(label) == LABEL_OTHER {
                assert_eq!(custom_label_bytes(&buf).unwrap(), label.as_bytes());
            } else {
                assert!(custom_label_bytes(&buf).is_none());
            }
        }
    }

    #[test]
    fn truncated_records_never_panic() {
        let buf = rec("weird", "f", Some(9));
        for n in 0..buf.len() {
            let t = &buf[..n];
            // All accessors must degrade, not panic.
            let _ = func_bytes(t);
            let _ = custom_label_bytes(t);
            let _ = label_eq(t, "weird");
            let _ = func_eq(t, "f");
            let _ = load_field(t, FIELD_SCORE);
        }
    }

    #[test]
    fn eval_faults_yield_false() {
        let buf = rec("normal", "f", None);
        // Unverified garbage: unknown opcode.
        let p = Program { consts: vec![], code: vec![77, OP_RET] };
        assert!(!eval(&p, &buf));
        // Missing RET.
        let p = Program { consts: vec![], code: vec![OP_LOAD, FIELD_ANOMALY] };
        assert!(!eval(&p, &buf));
        // Stack underflow.
        let p = Program { consts: vec![], code: vec![OP_NOT, OP_RET] };
        assert!(!eval(&p, &buf));
        // Over-long code is refused outright.
        let p = Program { consts: vec![], code: vec![0u8; MAX_CODE + 1] };
        assert!(!eval(&p, &buf));
    }

    #[test]
    fn u64_comparisons_stay_exact_above_2_pow_53() {
        // step = 2^53 + 1 vs literal 2^53: distinct as u64, equal as f64.
        let mut buf = rec("normal", "f", None);
        let step = (1u64 << 53) + 1;
        buf[12..20].copy_from_slice(&step.to_le_bytes());
        let p = Program {
            consts: vec![Const::U(1u64 << 53)],
            code: vec![OP_LOAD, FIELD_STEP, OP_CONST, 0, 0, OP_EQ, OP_RET],
        };
        p.verify().unwrap();
        assert!(!eval(&p, &buf), "u64 compare must not collapse through f64");
        let p = Program {
            consts: vec![Const::U(step)],
            code: vec![OP_LOAD, FIELD_STEP, OP_CONST, 0, 0, OP_EQ, OP_RET],
        };
        assert!(eval(&p, &buf));
    }

    #[test]
    fn nan_score_fails_ordered_comparisons() {
        let mut buf = rec("normal", "f", None);
        buf[36..44].copy_from_slice(&f64::NAN.to_le_bytes());
        for op in [OP_LT, OP_LE, OP_GT, OP_GE, OP_EQ] {
            let p = Program {
                consts: vec![Const::F(0.0)],
                code: vec![OP_LOAD, FIELD_SCORE, OP_CONST, 0, 0, op, OP_RET],
            };
            assert!(!eval(&p, &buf), "NaN must fail op {op}");
        }
        let p = Program {
            consts: vec![Const::F(0.0)],
            code: vec![OP_LOAD, FIELD_SCORE, OP_CONST, 0, 0, OP_NE, OP_RET],
        };
        assert!(eval(&p, &buf), "NaN != x is true");
    }
}
