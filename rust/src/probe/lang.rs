//! The probe language: hand-written lexer and recursive-descent parser
//! producing the AST the compiler lowers to bytecode.
//!
//! ```text
//! program  := probe*
//! probe    := [ "probe" NAME ":" ] site [ "/" expr "/" ]
//!             [ "sample" rate ] [ "{" action* "}" ]
//! site     := "fn" ":" appspec "." funcspec ":" ( "entry" | "exit" )
//! appspec  := "*" | INT
//! funcspec := "*" | IDENT | STRING
//! rate     := INT "%" | INT "/" INT
//! action   := "capture" "(" ( "record" | "stack" ) ")" ";"
//! expr     := or-expression over comparisons, arithmetic, "!", parens
//! fields   := app rank fid step entry_us exit_us score anomaly label func
//! ```
//!
//! `#` starts a line comment. Inside a predicate, a `/` at parenthesis
//! depth zero *closes* the predicate (DTrace-style delimiters); use
//! parentheses to divide: `/ (exclusive_us / 1000) > 5 /` is a parse
//! error while `/ score > (step / 2) /` is fine. The parser caps source
//! size and probe count so untrusted sources cannot over-allocate.

use anyhow::{bail, ensure, Result};

use super::bytecode::field_of_name;

/// Source cap for untrusted probe text (wire installs, files).
pub const MAX_SOURCE: usize = 64 << 10;
/// Probes per source cap.
pub const MAX_PROBES: usize = 64;
/// Probe-name byte cap.
pub const MAX_NAME: usize = 128;

/// Probe attachment event. Provenance records describe *completed*
/// executions, so both events see the same records today; the
/// distinction is kept for display and forward compatibility.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Event {
    Entry,
    Exit,
}

impl Event {
    pub fn name(self) -> &'static str {
        match self {
            Event::Entry => "entry",
            Event::Exit => "exit",
        }
    }
}

/// Probe action. `capture(record)` pushes the matching record itself
/// (the default when no block is given); `capture(stack)` marks the
/// probe as a call-stack subscription — consumers fetch the surrounding
/// `(app, rank, step)` stack for each match.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Action {
    CaptureRecord,
    CaptureStack,
}

impl Action {
    pub fn name(self) -> &'static str {
        match self {
            Action::CaptureRecord => "capture(record)",
            Action::CaptureStack => "capture(stack)",
        }
    }
}

/// The probe site: which records the probe attaches to before the
/// predicate runs. `None` entries are `*` wildcards.
#[derive(Clone, Debug, PartialEq)]
pub struct Site {
    pub app: Option<u32>,
    pub func: Option<String>,
    pub event: Event,
}

/// Binary operators, source-level.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum BinOp {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
    And,
    Or,
    Add,
    Sub,
    Mul,
    Div,
}

/// Predicate expression AST.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    Int(u64),
    Float(f64),
    Str(String),
    Field(u8),
    Not(Box<Expr>),
    Neg(Box<Expr>),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

/// One parsed probe definition.
#[derive(Clone, Debug, PartialEq)]
pub struct ProbeDef {
    pub name: Option<String>,
    pub site: Site,
    pub pred: Option<Expr>,
    /// Keep `n` of every `m` matching records.
    pub sample: Option<(u32, u32)>,
    pub actions: Vec<Action>,
    /// Byte span of this probe in the source (for listings).
    pub span: (usize, usize),
}

// ---- lexer ---------------------------------------------------------------

#[derive(Clone, Debug, PartialEq)]
enum Tok {
    Ident(String),
    Int(u64),
    Float(f64),
    Str(String),
    Colon,
    Dot,
    Slash,
    Percent,
    LBrace,
    RBrace,
    LParen,
    RParen,
    Semi,
    Star,
    AndAnd,
    OrOr,
    Bang,
    EqEq,
    NotEq,
    Lt,
    Le,
    Gt,
    Ge,
    Plus,
    Minus,
}

/// (token, byte offset of its first character)
fn lex(src: &str) -> Result<Vec<(Tok, usize)>> {
    ensure!(src.len() <= MAX_SOURCE, "probe source too long ({} > {MAX_SOURCE})", src.len());
    let b = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < b.len() {
        let at = i;
        let c = b[i];
        match c {
            b' ' | b'\t' | b'\r' | b'\n' => i += 1,
            b'#' => {
                while i < b.len() && b[i] != b'\n' {
                    i += 1;
                }
            }
            b':' => {
                out.push((Tok::Colon, at));
                i += 1;
            }
            b'.' => {
                out.push((Tok::Dot, at));
                i += 1;
            }
            b'/' => {
                out.push((Tok::Slash, at));
                i += 1;
            }
            b'%' => {
                out.push((Tok::Percent, at));
                i += 1;
            }
            b'{' => {
                out.push((Tok::LBrace, at));
                i += 1;
            }
            b'}' => {
                out.push((Tok::RBrace, at));
                i += 1;
            }
            b'(' => {
                out.push((Tok::LParen, at));
                i += 1;
            }
            b')' => {
                out.push((Tok::RParen, at));
                i += 1;
            }
            b';' => {
                out.push((Tok::Semi, at));
                i += 1;
            }
            b'*' => {
                out.push((Tok::Star, at));
                i += 1;
            }
            b'+' => {
                out.push((Tok::Plus, at));
                i += 1;
            }
            b'-' => {
                out.push((Tok::Minus, at));
                i += 1;
            }
            b'&' => {
                ensure!(b.get(i + 1) == Some(&b'&'), "lone '&' at byte {at}");
                out.push((Tok::AndAnd, at));
                i += 2;
            }
            b'|' => {
                ensure!(b.get(i + 1) == Some(&b'|'), "lone '|' at byte {at}");
                out.push((Tok::OrOr, at));
                i += 2;
            }
            b'!' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::NotEq, at));
                    i += 2;
                } else {
                    out.push((Tok::Bang, at));
                    i += 1;
                }
            }
            b'=' => {
                ensure!(b.get(i + 1) == Some(&b'='), "lone '=' at byte {at} (use ==)");
                out.push((Tok::EqEq, at));
                i += 2;
            }
            b'<' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::Le, at));
                    i += 2;
                } else {
                    out.push((Tok::Lt, at));
                    i += 1;
                }
            }
            b'>' => {
                if b.get(i + 1) == Some(&b'=') {
                    out.push((Tok::Ge, at));
                    i += 2;
                } else {
                    out.push((Tok::Gt, at));
                    i += 1;
                }
            }
            b'"' => {
                i += 1;
                let mut s = String::new();
                loop {
                    match b.get(i) {
                        None => bail!("unterminated string at byte {at}"),
                        Some(b'"') => {
                            i += 1;
                            break;
                        }
                        Some(b'\\') => {
                            let esc = b.get(i + 1).ok_or_else(|| {
                                anyhow::anyhow!("unterminated escape at byte {i}")
                            })?;
                            s.push(match esc {
                                b'"' => '"',
                                b'\\' => '\\',
                                b'n' => '\n',
                                b't' => '\t',
                                other => bail!("unknown escape \\{} at byte {i}", *other as char),
                            });
                            i += 2;
                        }
                        Some(_) => {
                            // Consume one whole UTF-8 scalar.
                            let rest = &src[i..];
                            let ch = rest.chars().next().unwrap();
                            s.push(ch);
                            i += ch.len_utf8();
                        }
                    }
                }
                out.push((Tok::Str(s), at));
            }
            b'0'..=b'9' => {
                let start = i;
                while i < b.len() && b[i].is_ascii_digit() {
                    i += 1;
                }
                let mut float = false;
                if i < b.len() && b[i] == b'.' && b.get(i + 1).is_some_and(|d| d.is_ascii_digit()) {
                    float = true;
                    i += 1;
                    while i < b.len() && b[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
                    let mut j = i + 1;
                    if matches!(b.get(j), Some(b'+') | Some(b'-')) {
                        j += 1;
                    }
                    if b.get(j).is_some_and(|d| d.is_ascii_digit()) {
                        float = true;
                        i = j;
                        while i < b.len() && b[i].is_ascii_digit() {
                            i += 1;
                        }
                    }
                }
                let text = &src[start..i];
                if float {
                    let v: f64 = text
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad float literal '{text}' at byte {at}"))?;
                    out.push((Tok::Float(v), at));
                } else {
                    let v: u64 = text
                        .parse()
                        .map_err(|_| anyhow::anyhow!("integer literal '{text}' out of range at byte {at}"))?;
                    out.push((Tok::Int(v), at));
                }
            }
            c if c == b'_' || c.is_ascii_alphabetic() => {
                let start = i;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
                out.push((Tok::Ident(src[start..i].to_string()), at));
            }
            other => bail!("unexpected character '{}' at byte {at}", other as char),
        }
    }
    Ok(out)
}

// ---- parser --------------------------------------------------------------

struct Parser<'a> {
    toks: &'a [(Tok, usize)],
    pos: usize,
    src_len: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<&Tok> {
        self.toks.get(self.pos).map(|(t, _)| t)
    }

    fn at(&self) -> usize {
        self.toks
            .get(self.pos)
            .map(|(_, o)| *o)
            .unwrap_or(self.src_len)
    }

    fn next(&mut self) -> Result<Tok> {
        let t = self
            .toks
            .get(self.pos)
            .map(|(t, _)| t.clone())
            .ok_or_else(|| anyhow::anyhow!("unexpected end of probe source"))?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<()> {
        let at = self.at();
        let t = self.next()?;
        ensure!(&t == want, "expected {what} at byte {at}, found {t:?}");
        Ok(())
    }

    fn ident(&mut self, what: &str) -> Result<String> {
        let at = self.at();
        match self.next()? {
            Tok::Ident(s) => Ok(s),
            other => bail!("expected {what} at byte {at}, found {other:?}"),
        }
    }

    fn probe(&mut self) -> Result<ProbeDef> {
        let start = self.at();
        // Optional "probe NAME :" prefix.
        let mut name = None;
        if self.peek() == Some(&Tok::Ident("probe".into())) {
            self.pos += 1;
            let n = self.ident("probe name")?;
            ensure!(n.len() <= MAX_NAME, "probe name too long ({} > {MAX_NAME})", n.len());
            self.expect(&Tok::Colon, "':' after probe name")?;
            name = Some(n);
        }
        // Site: fn : appspec . funcspec : event
        let kw = self.ident("'fn'")?;
        ensure!(kw == "fn", "probe site must start with 'fn', found '{kw}'");
        self.expect(&Tok::Colon, "':' after 'fn'")?;
        let app = match self.next()? {
            Tok::Star => None,
            Tok::Int(v) => {
                ensure!(v <= u32::MAX as u64, "app id {v} out of u32 range");
                Some(v as u32)
            }
            other => bail!("expected app id or '*', found {other:?}"),
        };
        self.expect(&Tok::Dot, "'.' between app and func")?;
        let func = match self.next()? {
            Tok::Star => None,
            Tok::Ident(s) => Some(s),
            Tok::Str(s) => Some(s),
            other => bail!("expected function name or '*', found {other:?}"),
        };
        self.expect(&Tok::Colon, "':' before event")?;
        let event = match self.ident("'entry' or 'exit'")?.as_str() {
            "entry" => Event::Entry,
            "exit" => Event::Exit,
            other => bail!("unknown probe event '{other}' (entry|exit)"),
        };
        // Optional / predicate /
        let mut pred = None;
        if self.peek() == Some(&Tok::Slash) {
            self.pos += 1;
            pred = Some(self.expr_bp(0, 0)?);
            self.expect(&Tok::Slash, "closing '/' of predicate")?;
        }
        // Optional sample clause.
        let mut sample = None;
        if self.peek() == Some(&Tok::Ident("sample".into())) {
            self.pos += 1;
            let at = self.at();
            let n = match self.next()? {
                Tok::Int(v) => v,
                other => bail!("expected sample count at byte {at}, found {other:?}"),
            };
            let (n, m) = match self.next()? {
                Tok::Percent => (n, 100),
                Tok::Slash => match self.next()? {
                    Tok::Int(m) => (n, m),
                    other => bail!("expected sample denominator, found {other:?}"),
                },
                other => bail!("expected '%' or '/N' after sample count, found {other:?}"),
            };
            ensure!(m > 0 && m <= 1_000_000, "sample denominator {m} out of range (1..=1000000)");
            ensure!(n <= m, "sample rate {n}/{m} keeps more than everything");
            sample = Some((n as u32, m as u32));
        }
        // Optional action block.
        let mut actions = Vec::new();
        if self.peek() == Some(&Tok::LBrace) {
            self.pos += 1;
            while self.peek() != Some(&Tok::RBrace) {
                let kw = self.ident("'capture'")?;
                ensure!(kw == "capture", "unknown action '{kw}' (capture)");
                self.expect(&Tok::LParen, "'(' after capture")?;
                let what = self.ident("'record' or 'stack'")?;
                let act = match what.as_str() {
                    "record" => Action::CaptureRecord,
                    "stack" => Action::CaptureStack,
                    other => bail!("unknown capture target '{other}' (record|stack)"),
                };
                self.expect(&Tok::RParen, "')' after capture target")?;
                self.expect(&Tok::Semi, "';' after action")?;
                actions.push(act);
                ensure!(actions.len() <= 8, "too many actions in one probe");
            }
            self.pos += 1; // consume '}'
        }
        let end = self.at();
        Ok(ProbeDef {
            name,
            site: Site { app, func, event },
            pred,
            sample,
            actions,
            span: (start, end),
        })
    }

    /// Pratt-style expression parser. `depth` is parenthesis depth: at
    /// depth 0 a `/` closes the predicate instead of dividing.
    fn expr_bp(&mut self, min_bp: u8, depth: u32) -> Result<Expr> {
        let at = self.at();
        let mut lhs = match self.next()? {
            Tok::Int(v) => Expr::Int(v),
            Tok::Float(v) => Expr::Float(v),
            Tok::Str(s) => Expr::Str(s),
            Tok::Bang => Expr::Not(Box::new(self.expr_bp(60, depth)?)),
            Tok::Minus => Expr::Neg(Box::new(self.expr_bp(60, depth)?)),
            Tok::LParen => {
                ensure!(depth < 32, "predicate nesting too deep");
                let e = self.expr_bp(0, depth + 1)?;
                self.expect(&Tok::RParen, "')'")?;
                e
            }
            Tok::Ident(s) => match field_of_name(&s) {
                Some(f) => Expr::Field(f),
                None => bail!("unknown field '{s}' at byte {at}"),
            },
            other => bail!("unexpected token {other:?} in predicate at byte {at}"),
        };
        loop {
            let (op, bp) = match self.peek() {
                Some(Tok::OrOr) => (BinOp::Or, 10),
                Some(Tok::AndAnd) => (BinOp::And, 20),
                Some(Tok::EqEq) => (BinOp::Eq, 30),
                Some(Tok::NotEq) => (BinOp::Ne, 30),
                Some(Tok::Lt) => (BinOp::Lt, 30),
                Some(Tok::Le) => (BinOp::Le, 30),
                Some(Tok::Gt) => (BinOp::Gt, 30),
                Some(Tok::Ge) => (BinOp::Ge, 30),
                Some(Tok::Plus) => (BinOp::Add, 40),
                Some(Tok::Minus) => (BinOp::Sub, 40),
                Some(Tok::Star) => (BinOp::Mul, 50),
                // `/` divides only inside parentheses; at depth 0 it
                // terminates the predicate (the caller consumes it).
                Some(Tok::Slash) if depth > 0 => (BinOp::Div, 50),
                _ => break,
            };
            if bp < min_bp {
                break;
            }
            self.pos += 1;
            let rhs = self.expr_bp(bp + 1, depth)?;
            lhs = Expr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }
}

/// Parse every probe in `src`.
pub fn parse_program(src: &str) -> Result<Vec<ProbeDef>> {
    let toks = lex(src)?;
    let mut p = Parser { toks: &toks, pos: 0, src_len: src.len() };
    let mut out = Vec::new();
    while p.peek().is_some() {
        out.push(p.probe()?);
        ensure!(out.len() <= MAX_PROBES, "too many probes in one source (> {MAX_PROBES})");
    }
    ensure!(!out.is_empty(), "no probes in source");
    Ok(out)
}

/// Parse exactly one probe.
pub fn parse_one(src: &str) -> Result<ProbeDef> {
    let all = parse_program(src)?;
    ensure!(all.len() == 1, "expected exactly one probe, found {}", all.len());
    Ok(all.into_iter().next().unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::probe::bytecode::{FIELD_LABEL, FIELD_SCORE};

    #[test]
    fn parses_the_readme_probe() {
        let d = parse_one("fn:0.md_force:exit / score > 0.9 / sample 1% { capture(stack); }")
            .unwrap();
        assert_eq!(d.site.app, Some(0));
        assert_eq!(d.site.func.as_deref(), Some("md_force"));
        assert_eq!(d.site.event, Event::Exit);
        assert_eq!(d.sample, Some((1, 100)));
        assert_eq!(d.actions, vec![Action::CaptureStack]);
        assert!(matches!(
            d.pred,
            Some(Expr::Bin(BinOp::Gt, ref l, ref r))
                if **l == Expr::Field(FIELD_SCORE) && **r == Expr::Float(0.9)
        ));
    }

    #[test]
    fn parses_wildcards_names_and_fractions() {
        let src = "probe hot: fn:*.*:entry / anomaly && label == \"weird\" / sample 3/7\n\
                   # comment\n\
                   fn:1.\"quoted name\":exit";
        let all = parse_program(src).unwrap();
        assert_eq!(all.len(), 2);
        assert_eq!(all[0].name.as_deref(), Some("hot"));
        assert_eq!(all[0].site.app, None);
        assert_eq!(all[0].site.func, None);
        assert_eq!(all[0].sample, Some((3, 7)));
        assert!(matches!(
            all[0].pred,
            Some(Expr::Bin(BinOp::And, _, ref r))
                if matches!(**r, Expr::Bin(BinOp::Eq, ref f, ref s)
                    if **f == Expr::Field(FIELD_LABEL) && **s == Expr::Str("weird".into()))
        ));
        assert_eq!(all[1].site.app, Some(1));
        assert_eq!(all[1].site.func.as_deref(), Some("quoted name"));
        assert!(all[1].pred.is_none());
        // Spans slice the original text.
        let s0 = &src[all[0].span.0..all[0].span.1];
        assert!(s0.starts_with("probe hot:"));
    }

    #[test]
    fn slash_closes_predicate_but_divides_in_parens() {
        let d = parse_one("fn:*.*:exit / (step / 2) >= 10 /").unwrap();
        assert!(matches!(
            d.pred,
            Some(Expr::Bin(BinOp::Ge, ref l, _))
                if matches!(**l, Expr::Bin(BinOp::Div, _, _))
        ));
        // Top-level '/' terminates: "step / 2 >= 10" parses as predicate
        // `step`, then the '/' closes, then "2 >= 10" is junk.
        assert!(parse_one("fn:*.*:exit / step / 2 >= 10 /").is_err());
    }

    #[test]
    fn precedence_and_unary() {
        let d = parse_one("fn:*.*:exit / !anomaly || score + 1.0 > 2.0 && step < 5 /").unwrap();
        // Or at top: (!anomaly) || ((score+1>2) && (step<5))
        let Some(Expr::Bin(BinOp::Or, l, r)) = d.pred else { panic!("want Or") };
        assert!(matches!(*l, Expr::Not(_)));
        assert!(matches!(*r, Expr::Bin(BinOp::And, _, _)));
        let d = parse_one("fn:*.*:exit / score >= -1.5 /").unwrap();
        assert!(matches!(
            d.pred,
            Some(Expr::Bin(BinOp::Ge, _, ref r)) if matches!(**r, Expr::Neg(_))
        ));
    }

    #[test]
    fn string_escapes_and_unicode() {
        let d = parse_one("fn:*.*:exit / label == \"a\\\"b\\\\c\\n\\tünï\" /").unwrap();
        let Some(Expr::Bin(BinOp::Eq, _, r)) = d.pred else { panic!() };
        assert_eq!(*r, Expr::Str("a\"b\\c\n\tünï".into()));
    }

    #[test]
    fn rejects_garbage_sources() {
        for bad in [
            "",
            "fn",
            "fn:0",
            "fn:0.f",
            "fn:0.f:later",
            "fn:0.f:exit / score > /",
            "fn:0.f:exit / score ~ 1 /",
            "fn:0.f:exit / nosuchfield > 1 /",
            "fn:0.f:exit / score > 1",
            "fn:0.f:exit sample 5",
            "fn:0.f:exit sample 7/3", // keeps more than everything
            "fn:0.f:exit sample 1/0",
            "fn:0.f:exit { explode(); }",
            "fn:0.f:exit { capture(record) }", // missing ';'
            "fn:0.f:exit / label == \"unterminated /",
            "fn:0.f:exit / 99999999999999999999999 > 1 /",
            "probe : fn:0.f:exit",
            "fn:4294967296.f:exit", // app > u32
        ] {
            assert!(parse_program(bad).is_err(), "must reject: {bad}");
        }
    }

    #[test]
    fn caps_hold() {
        let big = "x".repeat(MAX_SOURCE + 1);
        assert!(parse_program(&big).is_err());
        let many = "fn:*.*:exit\n".repeat(MAX_PROBES + 1);
        assert!(parse_program(&many).is_err());
        let long_name = format!("probe {}: fn:*.*:exit", "n".repeat(MAX_NAME + 1));
        assert!(parse_program(&long_name).is_err());
    }
}
