//! **Hierarchical aggregation tree** — the aggregator, spread out.
//!
//! After the stat plane was sharded (PR 1/3/4) and the transport became
//! a reactor (PR 6), the aggregator remained one thread in one process:
//! every `Report` from every rank funnelled through it, so at O(100k)
//! ranks it is the last single point in the PS constellation. This
//! module replaces it with a **tree of aggregator nodes** of fanout `F`:
//!
//! * **Leaves** own contiguous rank-ranges of the step timeline. Each
//!   folds its ranks' [`StepStat`] reports into per-rank summaries and a
//!   range-local step quorum; when every rank in the range has reported
//!   a step, the leaf pushes one [`PartialStep`] — `(step, count,
//!   anoms)` — to its parent. O(ranks) report traffic becomes
//!   O(ranks / F) partial traffic at the first fold.
//! * **Interior nodes** fold child partials (the commutative
//!   [`VizSnapshot::merge`] is the snapshot fold; [`StepFold`] is the
//!   quorum fold) and push range partials upward the same way.
//! * **The root** embeds the classic [`ParameterServer`] fed through
//!   [`ParameterServer::fold_partial_step`]: it alone runs the §V
//!   global-event trigger, owns the monotonic event version, and owns
//!   the per-rank delivery cursors — so the exactly-once, *next-sync*
//!   event-delivery invariant of the event-fetch gating protocol is
//!   preserved verbatim (fetches ride the same FIFO edges as reports,
//!   so a rank's fetch can never overtake its own report).
//!
//! The flat aggregator is the degenerate `F = ∞, depth = 1` case and
//! remains the code path when `ps.agg_fanout` is 0 (default) or the
//! rank count fits one node; `tests/aggtree.rs` pins the tree
//! **bit-equivalent** to it — published snapshots, global events, and
//! delivery order — for fanouts {2, 4} and depths {2, 3}.
//!
//! ## Deterministic publishes: the flush barrier
//!
//! The flat aggregator publishes inline with the report that completes
//! the cadence, so its deltas partition the input stream exactly. The
//! tree reproduces that boundary with **generation-stamped flush
//! barriers**: the ingress router broadcasts `Flush{gen}` down every
//! node's FIFO edge at the cadence point; a node completes generation
//! `g` once it has its own marker and a `FlushUp{g}` from every
//! in-process child, then folds the child deltas in child order and
//! forwards one combined delta up. Two rules make the boundary exact
//! while the subtrees drain at different speeds:
//!
//! 1. a node holding an incomplete generation *defers* any
//!    ingress-originated message that arrived after its own marker;
//! 2. it *stashes* messages from any child that has already flushed the
//!    oldest incomplete generation (per-child FIFO preserved, replayed
//!    on completion).
//!
//! Both queues drain the moment the generation completes, so the only
//! cost is latency bounded by the slowest subtree.
//!
//! The barrier also carries the **global expiry horizon** (the newest
//! step the ingress has seen in any report). Each range fold reconciles
//! against it when it acts on the barrier: accumulators a silent range
//! stranded behind the horizon drain up to the root tagged `expired`,
//! where they fold into the step statistics exactly when — and combined
//! exactly as — the flat aggregator's per-report expiry would have
//! folded them. Without this a whole-range outage would freeze the
//! leaf's fold (its own `max_step_seen` never advances) and the root
//! would later shed the stranded counts as stragglers;
//! `tests/aggtree.rs::whole_range_outage_expires_on_the_flat_schedule`
//! pins the schedule bit for bit.
//!
//! ## Remote nodes
//!
//! A leaf may run as a separate `chimbuko agg-node` process behind the
//! reactor (`serve_frames`) substrate; its parent owns the connection
//! and *escorts* each report and fetch through a request/reply
//! round-trip (kinds 13–16 in [`net`]), which keeps the report→fetch
//! serialization without server push. See `docs/aggtree.md`.

pub mod net;

use crate::ps::{
    AggNodeLoad, GlobalEvent, ParameterServer, PsRequest, RankSummary, StepStat, VizSnapshot,
    STEP_ACC_MAX_LAG,
};
use crate::stats::RunStats;
use anyhow::Result;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// A range quorum contribution travelling up the tree: `count` rank
/// reports for `step` totalling `anoms` anomalies.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) struct PartialStep {
    pub step: u64,
    pub count: u64,
    pub anoms: u64,
}

/// Tree topology derived from `(fanout, ranks)`: contiguous rank-ranges
/// at the leaves, `fanout`-ary reduction above them, one root.
#[derive(Clone, Debug)]
pub struct TreeSpec {
    pub fanout: usize,
    pub ranks: usize,
    /// Node count per level, leaves first; the last level is the root.
    pub levels: Vec<usize>,
}

impl TreeSpec {
    /// Plan a tree: `ceil(ranks / fanout)` leaves, then `fanout`-ary
    /// reduction until one node remains. `fanout` is clamped to ≥ 2 and
    /// `ranks` to ≥ 1.
    pub fn plan(fanout: usize, ranks: usize) -> TreeSpec {
        let fanout = fanout.max(2);
        let ranks = ranks.max(1);
        let mut levels = vec![ranks.div_ceil(fanout).max(1)];
        while *levels.last().expect("non-empty levels") > 1 {
            levels.push(levels.last().expect("non-empty levels").div_ceil(fanout));
        }
        TreeSpec { fanout, ranks, levels }
    }

    /// Levels in the tree (1 = a lone root that is also the only leaf —
    /// the degenerate case callers route to the flat aggregator).
    pub fn depth(&self) -> usize {
        self.levels.len()
    }

    pub fn leaves(&self) -> usize {
        self.levels[0]
    }

    /// Total node count, root included.
    pub fn nodes(&self) -> usize {
        self.levels.iter().sum()
    }

    fn rank_span(&self) -> usize {
        self.ranks.div_ceil(self.leaves())
    }

    /// The leaf owning `rank` (out-of-range ranks clamp to the last
    /// leaf, mirroring the flat aggregator's accept-anything behaviour).
    pub fn leaf_of_rank(&self, rank: u32) -> usize {
        ((rank as usize) / self.rank_span()).min(self.leaves() - 1)
    }

    /// Contiguous `[lo, hi)` rank-range of leaf `i`.
    pub fn leaf_range(&self, i: usize) -> (u32, u32) {
        let span = self.rank_span();
        let lo = i * span;
        let hi = ((i + 1) * span).min(self.ranks);
        (lo as u32, hi as u32)
    }

    /// Tree-wide node id for the node at `(level, index)`: the root is
    /// 0, then nodes are numbered level by level toward the leaves.
    pub fn node_id(&self, level: usize, index: usize) -> u32 {
        let above: usize = self.levels[level + 1..].iter().sum();
        (above + index) as u32
    }

    /// Distance from the root (root = 0).
    pub fn node_depth(&self, level: usize) -> u32 {
        (self.levels.len() - 1 - level) as u32
    }

    /// Leaf-index range `[lo, hi)` covered by node `(level, index)`.
    fn leaf_span(&self, level: usize, index: usize) -> (usize, usize) {
        let mut lo = index;
        let mut hi = index + 1;
        for _ in 0..level {
            lo = lo.saturating_mul(self.fanout);
            hi = hi.saturating_mul(self.fanout);
        }
        (lo.min(self.leaves()), hi.min(self.leaves()))
    }

    /// Contiguous rank-range `[lo, hi)` owned by node `(level, index)`.
    pub fn node_range(&self, level: usize, index: usize) -> (u32, u32) {
        let (llo, lhi) = self.leaf_span(level, index);
        (self.leaf_range(llo).0, self.leaf_range(lhi - 1).1)
    }

    /// Child count of node `(level, index)` (level ≥ 1).
    fn child_count(&self, level: usize, index: usize) -> usize {
        let below = self.levels[level - 1];
        (below - index * self.fanout).min(self.fanout)
    }
}

/// What a flush generation does once the barrier completes.
pub(crate) enum FlushKind {
    /// Fold and forward a delta; the root sends it to the merge stage.
    Publish,
    /// Fold absolute snapshots; the root answers the sender.
    Query(Sender<VizSnapshot>),
    /// Final publish + absolute fold; every node exits after acting.
    Shutdown,
    /// Like `Shutdown` but without the final publish — the ingress
    /// channel disconnected without a `Shutdown` request, and the flat
    /// aggregator does not publish on that path either.
    Halt,
}

impl FlushKind {
    fn clone_for_broadcast(&self) -> FlushKind {
        match self {
            FlushKind::Publish => FlushKind::Publish,
            FlushKind::Query(tx) => FlushKind::Query(tx.clone()),
            FlushKind::Shutdown => FlushKind::Shutdown,
            FlushKind::Halt => FlushKind::Halt,
        }
    }

    fn exits(&self) -> bool {
        matches!(self, FlushKind::Shutdown | FlushKind::Halt)
    }
}

/// Messages on the tree's channel edges.
pub(crate) enum TreeMsg {
    /// Ingress → leaf: one rank's report.
    Report(StepStat),
    /// Ingress → leaf: the event-fetch leg of a sync. Forwarded up the
    /// leaf's path so it serializes behind the rank's earlier reports.
    Fetch {
        app: u32,
        rank: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<crate::ps::PsReply>,
    },
    /// Ingress → parent of a *remote* leaf: escort the report through
    /// the child's wire (request/reply keeps the FIFO invariant).
    RemoteReport { child: usize, stat: StepStat },
    /// Ingress → parent of a remote leaf: escort the fetch through the
    /// child's wire, then forward it up toward the root's cursors.
    RemoteFetch {
        child: usize,
        app: u32,
        rank: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<crate::ps::PsReply>,
    },
    /// Child → parent: a completed (or straggler) range quorum.
    /// `expired` marks a partial a child's fold expired against the
    /// flush horizon — relayed untouched to the root, which folds it
    /// into the step statistics instead of shedding it as a straggler.
    Partial { from: usize, p: PartialStep, expired: bool },
    /// Child → parent: a fetch climbing toward the root.
    UpFetch {
        from: usize,
        app: u32,
        rank: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<crate::ps::PsReply>,
    },
    /// Ingress → every node: flush-barrier marker for generation `gen`.
    /// `horizon` is the newest step the ingress has seen in any report —
    /// the global expiry horizon every range fold reconciles against
    /// when it acts on the barrier.
    Flush { gen: u64, kind: FlushKind, horizon: u64 },
    /// Child → parent: the child's folded contribution to generation
    /// `gen` (`fin` = absolute final snapshot, Shutdown/Halt only).
    FlushUp { from: usize, gen: u64, delta: VizSnapshot, fin: Option<VizSnapshot> },
}

/// Range-local step-quorum fold shared by leaves and interior nodes:
/// the counterpart of the flat aggregator's `step_acc` map, completing
/// at `width` (the ranks in this node's range) instead of the global
/// quorum, with the same step-distance expiry.
pub(crate) struct StepFold {
    width: u64,
    acc: HashMap<u64, (u64, u64)>,
    max_step_seen: u64,
    /// Completed quorums pushed to the parent.
    pushed: u64,
    /// Expired accumulators + straggler contributions short-circuited.
    shed: u64,
    /// Expired partial quorums awaiting the next flush drain
    /// ([`take_expired`](Self::take_expired)); they keep their partial
    /// counts so the root's accounting still sees them.
    expired: Vec<PartialStep>,
}

impl StepFold {
    pub(crate) fn new(width: u64) -> StepFold {
        StepFold {
            width: width.max(1),
            acc: HashMap::new(),
            max_step_seen: 0,
            pushed: 0,
            shed: 0,
            expired: Vec::new(),
        }
    }

    /// Fold one contribution; completed quorums are appended to `out`
    /// (as are stragglers past the horizon — the root sheds those the
    /// same way the flat aggregator sheds straggler reports). Expired
    /// partial quorums go to the flush buffer instead, to ride the next
    /// barrier up to the root's step statistics.
    pub(crate) fn fold(&mut self, p: PartialStep, out: &mut Vec<PartialStep>) {
        if p.step > self.max_step_seen {
            self.max_step_seen = p.step;
            self.expire();
        }
        if p.step < self.max_step_seen.saturating_sub(STEP_ACC_MAX_LAG) {
            // Straggler past the expiry horizon: forward it as its own
            // partial (the root short-circuits it the same way the flat
            // aggregator short-circuits straggler reports).
            self.shed += 1;
            out.push(p);
            return;
        }
        let e = self.acc.entry(p.step).or_insert((0, 0));
        e.0 += p.count;
        e.1 += p.anoms;
        if e.0 >= self.width {
            let (count, anoms) = self.acc.remove(&p.step).expect("entry just updated");
            self.pushed += 1;
            out.push(PartialStep { step: p.step, count, anoms });
        }
    }

    fn expire(&mut self) {
        let horizon = self.max_step_seen.saturating_sub(STEP_ACC_MAX_LAG);
        if horizon == 0 {
            return;
        }
        self.drain_below(horizon);
    }

    fn drain_below(&mut self, horizon: u64) {
        let mut stale: Vec<u64> = self.acc.keys().filter(|&&s| s < horizon).copied().collect();
        stale.sort_unstable();
        for s in stale {
            if let Some((count, anoms)) = self.acc.remove(&s) {
                self.shed += 1;
                self.expired.push(PartialStep { step: s, count, anoms });
            }
        }
    }

    /// Reconcile with the tree-wide horizon `h` (the newest step the
    /// ingress has seen in any report): a range whose ranks all went
    /// silent never advances its own `max_step_seen`, so without this
    /// its stalled accumulators would outlive the expiry schedule the
    /// flat aggregator follows. The drain runs one lag-slot *ahead* of
    /// the root's own strictly-below-horizon sweep — the drained
    /// contributions must already sit in the root's accumulator when its
    /// horizon passes them, so each stalled step folds into the step
    /// statistics as one combined push on the flat schedule.
    pub(crate) fn advance_global(&mut self, h: u64) {
        if h > self.max_step_seen {
            self.max_step_seen = h;
        }
        if self.max_step_seen >= STEP_ACC_MAX_LAG {
            self.drain_below(self.max_step_seen - STEP_ACC_MAX_LAG + 1);
        }
    }

    /// Drain the partials expired since the last flush; they travel up
    /// tagged `expired` so the root folds them into the step statistics
    /// (the flat aggregator's expiry) instead of shedding them.
    pub(crate) fn take_expired(&mut self) -> Vec<PartialStep> {
        std::mem::take(&mut self.expired)
    }
}

/// A leaf's rank-plane state: per-rank summaries, fresh step list, and
/// the range quorum — everything the flat aggregator keys by rank,
/// minus events and cursors (the root owns those). Also the state
/// behind a remote `agg-node` process ([`net::AggNodeServer`]).
pub(crate) struct LeafState {
    node: u32,
    depth: u32,
    lo: u32,
    hi: u32,
    per_rank: HashMap<(u32, u32), (RunStats, u64)>,
    dirty: HashSet<(u32, u32)>,
    fresh: Vec<StepStat>,
    total_anomalies: u64,
    total_executions: u64,
    fold: StepFold,
    folds: u64,
}

impl LeafState {
    pub(crate) fn new(node: u32, depth: u32, lo: u32, hi: u32) -> LeafState {
        LeafState {
            node,
            depth,
            lo,
            hi,
            per_rank: HashMap::new(),
            dirty: HashSet::new(),
            fresh: Vec::new(),
            total_anomalies: 0,
            total_executions: 0,
            fold: StepFold::new((hi.saturating_sub(lo)) as u64),
            folds: 0,
        }
    }

    /// Fold one rank report; completed range quorums land in `out`.
    /// Mirrors the flat `Report` path field for field (minus the global
    /// trigger, which runs at the root).
    pub(crate) fn report(&mut self, stat: StepStat, out: &mut Vec<PartialStep>) {
        self.folds += 1;
        self.dirty.insert((stat.app, stat.rank));
        let acc = self
            .per_rank
            .entry((stat.app, stat.rank))
            .or_insert_with(|| (RunStats::new(), 0));
        acc.0.push(stat.n_anomalies as f64);
        acc.1 += stat.n_anomalies;
        self.total_anomalies += stat.n_anomalies;
        self.total_executions += stat.n_executions;
        self.fold.fold(
            PartialStep { step: stat.step, count: 1, anoms: stat.n_anomalies },
            out,
        );
        self.fresh.push(stat);
    }

    /// Flush-leg horizon reconciliation: raise the range fold's expiry
    /// horizon to the tree-wide newest step and drain what that expired
    /// (see [`StepFold::advance_global`]).
    pub(crate) fn reconcile_horizon(&mut self, horizon: u64) -> Vec<PartialStep> {
        self.fold.advance_global(horizon);
        self.fold.take_expired()
    }

    pub(crate) fn load(&self) -> AggNodeLoad {
        AggNodeLoad {
            node: self.node,
            depth: self.depth,
            rank_lo: self.lo,
            rank_hi: self.hi,
            folds: self.folds,
            pushed: self.fold.pushed,
            shed: self.fold.shed,
        }
    }

    fn ranks_sorted(&self, keys: impl Iterator<Item = (u32, u32)>) -> Vec<RankSummary> {
        let mut ranks: Vec<RankSummary> = keys
            .filter_map(|(app, rank)| {
                self.per_rank.get(&(app, rank)).map(|(step_counts, total)| RankSummary {
                    app,
                    rank,
                    step_counts: *step_counts,
                    total_anomalies: *total,
                })
            })
            .collect();
        ranks.sort_by_key(|r| (r.app, r.rank));
        ranks
    }

    /// Drain this leaf's delta contribution (the counterpart of
    /// [`ParameterServer::take_delta`]).
    pub(crate) fn delta(&mut self) -> VizSnapshot {
        let ranks = self.ranks_sorted(self.dirty.iter().copied());
        self.dirty.clear();
        VizSnapshot {
            ranks,
            fresh_steps: std::mem::take(&mut self.fresh),
            total_anomalies: self.total_anomalies,
            total_executions: self.total_executions,
            functions_tracked: 0,
            global_events: Vec::new(),
            shard_loads: Vec::new(),
            agg_nodes: vec![self.load()],
            placement_epoch: 0,
            delta: true,
        }
    }

    /// Absolute (non-draining) snapshot contribution.
    pub(crate) fn absolute(&self) -> VizSnapshot {
        VizSnapshot {
            ranks: self.ranks_sorted(self.per_rank.keys().copied()),
            fresh_steps: self.fresh.clone(),
            total_anomalies: self.total_anomalies,
            total_executions: self.total_executions,
            functions_tracked: 0,
            global_events: Vec::new(),
            shard_loads: Vec::new(),
            agg_nodes: vec![self.load()],
            placement_epoch: 0,
            delta: false,
        }
    }
}

/// An edge to one child, as the parent sees it.
enum ChildEdge {
    /// In-process child; it pushes to us, we never push to it.
    Local,
    /// Remote `agg-node` leaf; we own the wire and escort everything.
    Remote(crate::util::net::Reconnector<net::TreeWire>),
}

/// Barrier bookkeeping for one flush generation.
struct PendingGen {
    gen: u64,
    kind: Option<FlushKind>,
    /// Tree-wide newest step, from the generation's `Flush` marker.
    horizon: u64,
    deltas: Vec<Option<VizSnapshot>>,
    fins: Vec<Option<VizSnapshot>>,
    done: usize,
}

impl PendingGen {
    fn new(gen: u64, n_children: usize) -> PendingGen {
        PendingGen {
            gen,
            kind: None,
            horizon: 0,
            deltas: (0..n_children).map(|_| None).collect(),
            fins: (0..n_children).map(|_| None).collect(),
            done: 0,
        }
    }
}

/// Per-node event hook at the root: `(new_version, newly_flagged)` —
/// the seam `ps::shard` uses for trigger probes and version pushes.
pub type EventHook = Box<dyn FnMut(u64, &[GlobalEvent]) + Send>;

enum Role {
    Leaf(LeafState),
    Fold {
        fold: StepFold,
        folds: u64,
        meta: AggNodeLoad,
    },
    Root {
        ps: ParameterServer,
        job_tx: Sender<VizSnapshot>,
        on_version: EventHook,
        last_ver: u64,
        folds: u64,
        pushed: u64,
        shed: u64,
        meta: AggNodeLoad,
    },
}

/// The final state a shut-down tree hands back to `PsHandle::join`.
pub struct TreeFinal {
    /// The root's embedded reference server (events, cursors, synced
    /// global stats, sync counters).
    pub root: ParameterServer,
    /// Absolute fold of everything the root does not own: leaf rank
    /// summaries, totals, leftover fresh steps, per-node load counters.
    pub rest: VizSnapshot,
}

struct Node {
    rx: Receiver<TreeMsg>,
    parent: Option<Sender<TreeMsg>>,
    index_in_parent: usize,
    children: Vec<ChildEdge>,
    role: Role,
    pending: VecDeque<PendingGen>,
    child_done: Vec<u64>,
    stash: VecDeque<TreeMsg>,
    scratch: Vec<PartialStep>,
    fin: Option<TreeFinal>,
    exiting: bool,
}

impl Node {
    fn n_local_children(&self) -> usize {
        self.children.iter().filter(|c| matches!(c, ChildEdge::Local)).count()
    }

    fn run(mut self) -> Option<TreeFinal> {
        while !self.exiting {
            let msg = match self.rx.recv() {
                Ok(m) => m,
                Err(_) => break,
            };
            self.on_msg(msg);
        }
        self.fin.take()
    }

    fn on_msg(&mut self, msg: TreeMsg) {
        match msg {
            TreeMsg::Flush { gen, kind, horizon } => {
                let e = self.pending_entry(gen);
                e.kind = Some(kind);
                e.horizon = horizon;
                self.try_complete();
            }
            TreeMsg::FlushUp { from, gen, delta, fin } => {
                let e = self.pending_entry(gen);
                e.deltas[from] = Some(delta);
                e.fins[from] = fin;
                e.done += 1;
                self.child_done[from] = gen;
                self.try_complete();
            }
            other => self.dispatch(other),
        }
    }

    /// Route a data message: stash it if the flush barrier says it
    /// belongs to a later generation than the oldest incomplete one,
    /// process it otherwise.
    fn dispatch(&mut self, msg: TreeMsg) {
        let stash_it = match &msg {
            TreeMsg::Partial { from, .. } | TreeMsg::UpFetch { from, .. } => {
                self.blocked_child(*from)
            }
            TreeMsg::Report(_)
            | TreeMsg::Fetch { .. }
            | TreeMsg::RemoteReport { .. }
            | TreeMsg::RemoteFetch { .. } => self.blocked_ingress(),
            TreeMsg::Flush { .. } | TreeMsg::FlushUp { .. } => false,
        };
        if stash_it {
            self.stash.push_back(msg);
        } else {
            self.dispatch_data(msg);
        }
    }

    /// Deferral rule 2: a child that has flushed the oldest incomplete
    /// generation already — its further messages belong after it.
    fn blocked_child(&self, from: usize) -> bool {
        self.pending.front().is_some_and(|p| self.child_done[from] >= p.gen)
    }

    /// Deferral rule 1: our own marker for the oldest incomplete
    /// generation has arrived — later ingress traffic belongs after it.
    fn blocked_ingress(&self) -> bool {
        self.pending.front().is_some_and(|p| p.kind.is_some())
    }

    fn pending_entry(&mut self, gen: u64) -> &mut PendingGen {
        let pos = match self.pending.iter().position(|p| p.gen >= gen) {
            Some(i) if self.pending[i].gen == gen => i,
            Some(i) => {
                let n = self.children.len();
                self.pending.insert(i, PendingGen::new(gen, n));
                i
            }
            None => {
                let n = self.children.len();
                self.pending.push_back(PendingGen::new(gen, n));
                self.pending.len() - 1
            }
        };
        &mut self.pending[pos]
    }

    fn try_complete(&mut self) {
        loop {
            let complete = match self.pending.front() {
                Some(p) => p.kind.is_some() && p.done == self.n_local_children(),
                None => return,
            };
            if !complete {
                return;
            }
            let pg = self.pending.pop_front().expect("front just checked");
            self.act(pg);
            if self.exiting {
                return;
            }
            // Replay deferred traffic against the new oldest generation
            // (messages may re-stash; relative order is preserved).
            let stashed: Vec<TreeMsg> = self.stash.drain(..).collect();
            for m in stashed {
                self.dispatch(m);
            }
        }
    }

    fn dispatch_data(&mut self, msg: TreeMsg) {
        match msg {
            TreeMsg::Report(stat) => {
                if let Role::Leaf(state) = &mut self.role {
                    self.scratch.clear();
                    state.report(stat, &mut self.scratch);
                    let out = std::mem::take(&mut self.scratch);
                    for p in &out {
                        self.send_partial_up(*p);
                    }
                    self.scratch = out;
                } else {
                    debug_assert!(false, "Report routed to a non-leaf node");
                }
            }
            TreeMsg::Fetch { app, rank, delta, reply }
            | TreeMsg::UpFetch { app, rank, delta, reply, .. } => {
                self.up_fetch(app, rank, delta, reply);
            }
            TreeMsg::RemoteReport { child, stat } => {
                let partials = self.escort(child, |w| w.report(&stat));
                for p in partials {
                    self.fold_partial(p);
                }
            }
            TreeMsg::RemoteFetch { child, app, rank, delta, reply } => {
                let partials = self.escort(child, |w| w.fetch(app, rank));
                for p in partials {
                    self.fold_partial(p);
                }
                self.up_fetch(app, rank, delta, reply);
            }
            TreeMsg::Partial { p, expired, .. } => {
                if expired {
                    self.relay_expired(p);
                } else {
                    self.fold_partial(p);
                }
            }
            TreeMsg::Flush { .. } | TreeMsg::FlushUp { .. } => unreachable!("barrier msg"),
        }
        self.check_version();
    }

    /// Run one escorted round-trip against remote child `child`; wire
    /// failures degrade to a warning (that report/fetch window's
    /// contribution is lost; the reconnector redials on the next use).
    fn escort(
        &mut self,
        child: usize,
        op: impl FnMut(&mut net::TreeWire) -> Result<Vec<PartialStep>>,
    ) -> Vec<PartialStep> {
        match &mut self.children[child] {
            ChildEdge::Remote(rc) => match rc.with(op) {
                Ok(ps) => ps,
                Err(e) => {
                    crate::log_warn!("aggtree", "remote agg-node escort failed: {e:#}");
                    Vec::new()
                }
            },
            ChildEdge::Local => {
                debug_assert!(false, "escort to a local child");
                Vec::new()
            }
        }
    }

    fn send_partial_up(&mut self, p: PartialStep) {
        if let Some(parent) = &self.parent {
            let _ = parent.send(TreeMsg::Partial {
                from: self.index_in_parent,
                p,
                expired: false,
            });
        }
    }

    /// An expired partial climbing to the root: interiors relay it
    /// untouched (it already left a fold's accumulator — folding it
    /// again would re-open an entry its horizon closed); the root feeds
    /// it straight into the reference server's step accumulator, where
    /// the next horizon sweep folds the step's combined total.
    fn relay_expired(&mut self, p: PartialStep) {
        if let Role::Root { ps, pushed, .. } = &mut self.role {
            if ps.fold_expired_step(p.step, p.count, p.anoms) {
                *pushed += 1;
            }
        } else if let Some(parent) = &self.parent {
            let _ = parent.send(TreeMsg::Partial {
                from: self.index_in_parent,
                p,
                expired: true,
            });
        }
    }

    /// Fold a child partial: interiors accumulate toward their own range
    /// quorum, the root feeds the reference server's global quorum.
    fn fold_partial(&mut self, p: PartialStep) {
        match &mut self.role {
            Role::Fold { fold, folds, .. } => {
                *folds += 1;
                self.scratch.clear();
                fold.fold(p, &mut self.scratch);
                let out = std::mem::take(&mut self.scratch);
                for c in &out {
                    self.send_partial_up(*c);
                }
                self.scratch = out;
            }
            Role::Root { ps, folds, pushed, shed, .. } => {
                *folds += 1;
                match ps.fold_partial_step(p.step, p.count, p.anoms) {
                    None => *shed += 1,
                    Some(true) => *pushed += 1,
                    Some(false) => {}
                }
            }
            Role::Leaf(_) => debug_assert!(false, "Partial routed to a leaf"),
        }
    }

    /// A fetch reaching the root resolves against the delivery cursors;
    /// anywhere else it keeps climbing.
    fn up_fetch(
        &mut self,
        app: u32,
        rank: u32,
        delta: Vec<(u32, RunStats)>,
        reply: Sender<crate::ps::PsReply>,
    ) {
        match (&mut self.role, &self.parent) {
            (Role::Root { ps, .. }, _) => {
                ps.handle(PsRequest::Sync { app, rank, delta, reply });
            }
            (_, Some(parent)) => {
                let _ = parent.send(TreeMsg::UpFetch {
                    from: self.index_in_parent,
                    app,
                    rank,
                    delta,
                    reply,
                });
            }
            (_, None) => debug_assert!(false, "non-root node without a parent"),
        }
    }

    /// Root-only: fire the event hook when the version moved (the flat
    /// aggregator loop's post-handle version block).
    fn check_version(&mut self) {
        if let Role::Root { ps, on_version, last_ver, .. } = &mut self.role {
            let v = ps.event_version();
            if v != *last_ver {
                on_version(v, &ps.global_events()[*last_ver as usize..]);
                *last_ver = v;
            }
        }
    }

    /// Barrier completion: flush remote children synchronously, fold
    /// everything in child order, then forward up (or, at the root,
    /// publish / answer / finalize).
    fn act(&mut self, mut pg: PendingGen) {
        let kind = pg.kind.take().expect("completed gen has a kind");
        let horizon = pg.horizon;
        let mode = match kind {
            FlushKind::Publish => net::FLUSH_DELTA,
            FlushKind::Query(_) => net::FLUSH_ABSOLUTE,
            FlushKind::Shutdown | FlushKind::Halt => net::FLUSH_FINAL,
        };
        for i in 0..self.children.len() {
            if matches!(self.children[i], ChildEdge::Local) {
                continue;
            }
            let flushed = match &mut self.children[i] {
                ChildEdge::Remote(rc) => rc.with(|w| w.flush(mode, horizon)),
                ChildEdge::Local => unreachable!("filtered above"),
            };
            match flushed {
                Ok((expired, delta, fin)) => {
                    // The flush reply carries what the remote leaf's
                    // fold expired against the barrier's horizon.
                    for p in expired {
                        self.relay_expired(p);
                    }
                    pg.deltas[i] = Some(delta);
                    pg.fins[i] = fin;
                }
                Err(e) => {
                    // Degrade like the merge stage does on a dead shard:
                    // this flush proceeds without the subtree's
                    // contribution; the next one redials.
                    crate::log_warn!("aggtree", "remote agg-node flush failed: {e:#}");
                }
            }
        }
        // Reconcile this node's own range fold with the tree-wide
        // horizon before the FlushUp goes out, so every expired partial
        // reaches the root ahead of the root's own act for this
        // generation (FIFO per edge guarantees the ordering).
        let expired = match &mut self.role {
            Role::Leaf(state) => state.reconcile_horizon(horizon),
            Role::Fold { fold, .. } => {
                fold.advance_global(horizon);
                fold.take_expired()
            }
            Role::Root { .. } => Vec::new(),
        };
        for p in expired {
            self.relay_expired(p);
        }
        self.check_version();
        let fold_children = |pg: &mut PendingGen, into: &mut VizSnapshot, fins: bool| {
            let slots = if fins { &mut pg.fins } else { &mut pg.deltas };
            for slot in slots.iter_mut() {
                if let Some(d) = slot.take() {
                    into.merge(&d);
                }
            }
        };
        // Set in the Root arm; the shutdown epilogue runs after the
        // role borrow ends (it moves the server out of `self.role`).
        let mut root_load = None;
        match &mut self.role {
            Role::Leaf(state) => {
                let (delta, fin) = match kind {
                    FlushKind::Query(_) => (state.absolute(), None),
                    FlushKind::Shutdown | FlushKind::Halt => {
                        (state.delta(), Some(state.absolute()))
                    }
                    FlushKind::Publish => (state.delta(), None),
                };
                if let Some(parent) = &self.parent {
                    let _ = parent.send(TreeMsg::FlushUp {
                        from: self.index_in_parent,
                        gen: pg.gen,
                        delta,
                        fin,
                    });
                }
            }
            Role::Fold { fold, folds, meta } => {
                let mut combined = VizSnapshot::default();
                fold_children(&mut pg, &mut combined, false);
                let mut load = *meta;
                load.folds = *folds;
                load.pushed = fold.pushed;
                load.shed = fold.shed;
                combined.agg_nodes.push(load);
                combined.agg_nodes.sort_by_key(|n| n.node);
                combined.delta = !matches!(kind, FlushKind::Query(_));
                let fin = if kind.exits() {
                    let mut f = VizSnapshot::default();
                    fold_children(&mut pg, &mut f, true);
                    f.agg_nodes.push(load);
                    f.agg_nodes.sort_by_key(|n| n.node);
                    Some(f)
                } else {
                    None
                };
                if let Some(parent) = &self.parent {
                    let _ = parent.send(TreeMsg::FlushUp {
                        from: self.index_in_parent,
                        gen: pg.gen,
                        delta: combined,
                        fin,
                    });
                }
            }
            Role::Root { ps, job_tx, folds, pushed, shed, meta, .. } => {
                // Sweep the reference server's horizon up to the
                // tree-wide newest step: with every child's expired
                // partials already folded in (they arrive before the
                // FlushUps that completed this barrier), each stalled
                // step folds into the step statistics as one combined
                // push — the flat aggregator's expiry schedule.
                ps.expire_to(horizon);
                let mut load = *meta;
                load.folds = *folds;
                load.pushed = *pushed;
                load.shed = *shed;
                match &kind {
                    FlushKind::Publish => {
                        let mut d = ps.take_delta();
                        fold_children(&mut pg, &mut d, false);
                        d.agg_nodes.push(load);
                        d.agg_nodes.sort_by_key(|n| n.node);
                        d.delta = true;
                        let _ = job_tx.send(d);
                    }
                    FlushKind::Query(reply) => {
                        let mut s = ps.snapshot();
                        fold_children(&mut pg, &mut s, false);
                        s.agg_nodes.push(load);
                        s.agg_nodes.sort_by_key(|n| n.node);
                        s.delta = false;
                        let _ = reply.send(s);
                    }
                    FlushKind::Shutdown => {
                        // The final count-cadence publish, exactly like
                        // the flat aggregator's Shutdown handling.
                        let mut d = ps.take_delta();
                        fold_children(&mut pg, &mut d, false);
                        d.agg_nodes.push(load);
                        d.agg_nodes.sort_by_key(|n| n.node);
                        d.delta = true;
                        let _ = job_tx.send(d);
                    }
                    // Halt (ingress disconnect) exits without a final
                    // publish — the flat aggregator's Disconnected arm
                    // doesn't publish either.
                    FlushKind::Halt => {}
                }
                root_load = Some(load);
            }
        }
        if kind.exits() {
            if let Some(load) = root_load {
                self.finalize(pg, load);
            }
            self.exiting = true;
        }
    }

    /// Root shutdown epilogue: package the reference server + the
    /// absolute fold of the leaves' state for `PsHandle::join`.
    fn finalize(&mut self, mut pg: PendingGen, load: AggNodeLoad) {
        let mut rest = VizSnapshot::default();
        for slot in pg.fins.iter_mut() {
            if let Some(f) = slot.take() {
                rest.merge(&f);
            }
        }
        rest.agg_nodes.push(load);
        rest.agg_nodes.sort_by_key(|n| n.node);
        rest.delta = false;
        let role = std::mem::replace(
            &mut self.role,
            Role::Fold {
                fold: StepFold::new(1),
                folds: 0,
                meta: AggNodeLoad::default(),
            },
        );
        if let Role::Root { ps, .. } = role {
            self.fin = Some(TreeFinal { root: ps, rest });
        }
    }
}

/// Configuration for [`spawn_tree`].
pub struct TreeOpts {
    /// Aggregation fanout (≥ 2; the caller routes smaller values to the
    /// flat aggregator).
    pub fanout: usize,
    /// Reporting ranks — the global step quorum *and* the rank-range
    /// domain split across the leaves.
    pub ranks: usize,
    /// Publish cadence in reports (the flat aggregator's knob).
    pub publish_every: usize,
    /// Wall-clock publish cadence, ms (0 = count-only).
    pub publish_interval_ms: u64,
    /// Remote `agg-node` endpoints by leaf index ("" = in-process).
    pub endpoints: Vec<String>,
}

/// Handle to a running aggregation tree: the ingress sender speaks the
/// same [`PsRequest`] protocol as the flat aggregator's channel, so
/// `PsClient` routes to either without knowing which is behind it.
pub struct TreeHandle {
    ingress: Sender<PsRequest>,
    ingress_join: std::thread::JoinHandle<()>,
    node_joins: Vec<std::thread::JoinHandle<Option<TreeFinal>>>,
    pub spec: TreeSpec,
}

impl TreeHandle {
    pub fn request_sender(&self) -> Sender<PsRequest> {
        self.ingress.clone()
    }

    /// Join every thread; the root's final state comes back to the
    /// caller (`PsHandle::join` merges it with the shard partials).
    pub fn join(self) -> TreeFinal {
        drop(self.ingress);
        let _ = self.ingress_join.join();
        let mut fin = None;
        for j in self.node_joins {
            if let Ok(Some(f)) = j.join() {
                fin = Some(f);
            }
        }
        fin.expect("aggtree root exited without final state")
    }
}

/// Build and start the tree: one thread per in-process node plus the
/// ingress router. Remote leaf endpoints are dialled eagerly so a
/// mis-wired topology fails at spawn, not mid-run.
pub fn spawn_tree(
    opts: TreeOpts,
    job_tx: Sender<VizSnapshot>,
    on_version: EventHook,
) -> Result<TreeHandle> {
    let spec = TreeSpec::plan(opts.fanout, opts.ranks);
    anyhow::ensure!(
        spec.depth() >= 2,
        "aggtree needs at least 2 levels (got {} ranks at fanout {}); use the flat aggregator",
        opts.ranks,
        opts.fanout
    );
    let top = spec.levels.len() - 1;

    // Channels for every in-process node. Remote leaves have no channel:
    // their parent escorts traffic through the wire.
    let mut txs: HashMap<(usize, usize), Sender<TreeMsg>> = HashMap::new();
    let mut rxs: HashMap<(usize, usize), Receiver<TreeMsg>> = HashMap::new();
    let remote_leaf = |i: usize| -> Option<&str> {
        opts.endpoints.get(i).map(|s| s.as_str()).filter(|s| !s.is_empty())
    };
    for (level, &n) in spec.levels.iter().enumerate() {
        for index in 0..n {
            if level == 0 && remote_leaf(index).is_some() {
                continue;
            }
            let (tx, rx) = channel::<TreeMsg>();
            txs.insert((level, index), tx);
            rxs.insert((level, index), rx);
        }
    }

    let mut node_joins = Vec::with_capacity(spec.nodes());
    let mut role_for_root = Some(Role::Root {
        ps: ParameterServer::new(None, usize::MAX >> 1, opts.ranks),
        job_tx,
        on_version,
        last_ver: 0,
        folds: 0,
        pushed: 0,
        shed: 0,
        meta: AggNodeLoad {
            node: spec.node_id(top, 0),
            depth: 0,
            rank_lo: spec.node_range(top, 0).0,
            rank_hi: spec.node_range(top, 0).1,
            ..AggNodeLoad::default()
        },
    });
    for (level, &n) in spec.levels.iter().enumerate() {
        for index in 0..n {
            if level == 0 && remote_leaf(index).is_some() {
                continue;
            }
            let rx = rxs.remove(&(level, index)).expect("channel planned above");
            let (parent, index_in_parent) = if level == top {
                (None, 0)
            } else {
                let ptx = txs
                    .get(&(level + 1, index / spec.fanout))
                    .expect("parent channel planned above")
                    .clone();
                (Some(ptx), index % spec.fanout)
            };
            let children: Vec<ChildEdge> = if level == 0 {
                Vec::new()
            } else {
                let mut edges = Vec::new();
                for c in 0..spec.child_count(level, index) {
                    let ci = index * spec.fanout + c;
                    if level == 1 {
                        if let Some(ep) = remote_leaf(ci) {
                            let (clo, chi) = spec.leaf_range(ci);
                            let cid = spec.node_id(0, ci);
                            let wire = crate::util::net::Reconnector::connected(
                                ep,
                                move |a| net::TreeWire::connect(a, cid, clo, chi),
                            )?;
                            edges.push(ChildEdge::Remote(wire));
                            continue;
                        }
                    }
                    edges.push(ChildEdge::Local);
                }
                edges
            };
            let n_children = children.len();
            let id = spec.node_id(level, index);
            let (lo, hi) = spec.node_range(level, index);
            let role = if level == top {
                role_for_root.take().expect("single root")
            } else if level == 0 {
                Role::Leaf(LeafState::new(id, spec.node_depth(0), lo, hi))
            } else {
                Role::Fold {
                    fold: StepFold::new((hi - lo) as u64),
                    folds: 0,
                    meta: AggNodeLoad {
                        node: id,
                        depth: spec.node_depth(level),
                        rank_lo: lo,
                        rank_hi: hi,
                        ..AggNodeLoad::default()
                    },
                }
            };
            let node = Node {
                rx,
                parent,
                index_in_parent,
                children,
                role,
                pending: VecDeque::new(),
                child_done: vec![0; n_children],
                stash: VecDeque::new(),
                scratch: Vec::new(),
                fin: None,
                exiting: false,
            };
            let join = std::thread::Builder::new()
                .name(format!("chimbuko-aggtree-{id}"))
                .spawn(move || node.run())
                .expect("spawning aggtree node");
            node_joins.push(join);
        }
    }

    // Ingress routing table: rank → leaf channel, or (for remote leaves)
    // the parent channel plus the child slot to escort through.
    enum RouteEntry {
        Local(Sender<TreeMsg>),
        Remote { parent: Sender<TreeMsg>, child: usize },
    }
    let mut routes: Vec<RouteEntry> = Vec::with_capacity(spec.leaves());
    for i in 0..spec.leaves() {
        if remote_leaf(i).is_some() {
            let ptx = txs.get(&(1, i / spec.fanout)).expect("parent of leaf").clone();
            routes.push(RouteEntry::Remote { parent: ptx, child: i % spec.fanout });
        } else {
            routes.push(RouteEntry::Local(txs[&(0, i)].clone()));
        }
    }
    let broadcast: Vec<Sender<TreeMsg>> = {
        // Deterministic order (leaves first, then up); any order works —
        // each edge is its own FIFO.
        let mut keys: Vec<(usize, usize)> = txs.keys().copied().collect();
        keys.sort_unstable();
        keys.into_iter().map(|k| txs[&k].clone()).collect()
    };
    drop(txs);

    let (ingress_tx, ingress_rx) = channel::<PsRequest>();
    let publish_every = opts.publish_every.max(1);
    let interval_ms = opts.publish_interval_ms;
    let ingress_spec = spec.clone();
    let ingress_join = std::thread::Builder::new()
        .name("chimbuko-aggtree-ingress".into())
        .spawn(move || {
            let spec = ingress_spec;
            let mut gen = 0u64;
            let mut reports_since = 0usize;
            // Newest step seen in any report — the global expiry horizon
            // every flush barrier carries down to the range folds.
            let mut max_step = 0u64;
            let mut last_interval_pub = Instant::now();
            let mut flush = |kind: FlushKind, gen: &mut u64, reports_since: &mut usize, horizon: u64| {
                // A Query barrier collects absolutes without draining
                // deltas, so it leaves the publish cadence alone — the
                // flat aggregator's Query doesn't publish either.
                if !matches!(kind, FlushKind::Query(_)) {
                    *reports_since = 0;
                }
                *gen += 1;
                for tx in &broadcast {
                    let _ = tx.send(TreeMsg::Flush {
                        gen: *gen,
                        kind: kind.clone_for_broadcast(),
                        horizon,
                    });
                }
            };
            loop {
                let req = if interval_ms == 0 {
                    match ingress_rx.recv() {
                        Ok(r) => Some(r),
                        Err(_) => {
                            flush(FlushKind::Halt, &mut gen, &mut reports_since, max_step);
                            break;
                        }
                    }
                } else {
                    let budget = Duration::from_millis(interval_ms)
                        .saturating_sub(last_interval_pub.elapsed());
                    match ingress_rx.recv_timeout(budget.max(Duration::from_millis(1))) {
                        Ok(r) => Some(r),
                        Err(std::sync::mpsc::RecvTimeoutError::Timeout) => None,
                        Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                            flush(FlushKind::Halt, &mut gen, &mut reports_since, max_step);
                            break;
                        }
                    }
                };
                match req {
                    Some(PsRequest::Report(stat)) => {
                        max_step = max_step.max(stat.step);
                        let leaf = spec.leaf_of_rank(stat.rank);
                        match &routes[leaf] {
                            RouteEntry::Local(tx) => {
                                let _ = tx.send(TreeMsg::Report(stat));
                            }
                            RouteEntry::Remote { parent, child } => {
                                let _ = parent
                                    .send(TreeMsg::RemoteReport { child: *child, stat });
                            }
                        }
                        reports_since += 1;
                        if reports_since >= publish_every {
                            flush(FlushKind::Publish, &mut gen, &mut reports_since, max_step);
                        }
                        if interval_ms > 0
                            && last_interval_pub.elapsed()
                                >= Duration::from_millis(interval_ms)
                        {
                            if reports_since > 0 {
                                flush(FlushKind::Publish, &mut gen, &mut reports_since, max_step);
                            }
                            last_interval_pub = Instant::now();
                        }
                    }
                    Some(PsRequest::Sync { app, rank, delta, reply }) => {
                        let leaf = spec.leaf_of_rank(rank);
                        match &routes[leaf] {
                            RouteEntry::Local(tx) => {
                                let _ = tx.send(TreeMsg::Fetch { app, rank, delta, reply });
                            }
                            RouteEntry::Remote { parent, child } => {
                                let _ = parent.send(TreeMsg::RemoteFetch {
                                    child: *child,
                                    app,
                                    rank,
                                    delta,
                                    reply,
                                });
                            }
                        }
                    }
                    Some(PsRequest::Query { reply }) => {
                        flush(FlushKind::Query(reply), &mut gen, &mut reports_since, max_step);
                    }
                    Some(PsRequest::Publish) => {
                        flush(FlushKind::Publish, &mut gen, &mut reports_since, max_step);
                    }
                    Some(PsRequest::Shutdown) => {
                        flush(FlushKind::Shutdown, &mut gen, &mut reports_since, max_step);
                        break;
                    }
                    None => {
                        if reports_since > 0 {
                            flush(FlushKind::Publish, &mut gen, &mut reports_since, max_step);
                        }
                        last_interval_pub = Instant::now();
                    }
                }
            }
        })
        .expect("spawning aggtree ingress");

    Ok(TreeHandle { ingress: ingress_tx, ingress_join, node_joins, spec })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_shapes() {
        // (fanout, ranks) → (depth, leaves, nodes)
        let cases = [
            (2, 4, 2, 2, 3),
            (2, 8, 3, 4, 7),
            (4, 8, 2, 2, 3),
            (4, 64, 3, 16, 21),
            (8, 100_000, 6, 12500, 14289),
        ];
        for (f, r, depth, leaves, nodes) in cases {
            let s = TreeSpec::plan(f, r);
            assert_eq!(s.leaves(), leaves, "leaves for F={f} R={r}");
            assert_eq!(s.depth(), depth, "depth for F={f} R={r}");
            assert_eq!(s.nodes(), nodes, "nodes for F={f} R={r}");
        }
    }

    #[test]
    fn leaf_ranges_partition_ranks() {
        for (f, r) in [(2, 4), (2, 8), (4, 8), (4, 64), (3, 10), (7, 23), (2, 5)] {
            let s = TreeSpec::plan(f, r);
            let mut next = 0u32;
            for i in 0..s.leaves() {
                let (lo, hi) = s.leaf_range(i);
                assert_eq!(lo, next, "contiguous at leaf {i} (F={f} R={r})");
                assert!(hi > lo, "non-empty leaf {i} (F={f} R={r})");
                next = hi;
            }
            assert_eq!(next as usize, r, "ranges cover all ranks (F={f} R={r})");
            for rank in 0..r as u32 {
                let leaf = s.leaf_of_rank(rank);
                let (lo, hi) = s.leaf_range(leaf);
                assert!(lo <= rank && rank < hi, "rank {rank} in its leaf's range");
            }
        }
    }

    #[test]
    fn node_ranges_nest() {
        let s = TreeSpec::plan(2, 8); // 4 leaves, 2 interiors, 1 root
        assert_eq!(s.node_range(2, 0), (0, 8));
        assert_eq!(s.node_range(1, 0), (0, 4));
        assert_eq!(s.node_range(1, 1), (4, 8));
        assert_eq!(s.node_id(2, 0), 0);
        assert_eq!(s.node_id(1, 0), 1);
        assert_eq!(s.node_id(0, 3), 6);
        assert_eq!(s.node_depth(2), 0);
        assert_eq!(s.node_depth(0), 2);
    }

    #[test]
    fn step_fold_quorum_and_expiry() {
        let mut f = StepFold::new(3);
        let mut out = Vec::new();
        f.fold(PartialStep { step: 1, count: 1, anoms: 2 }, &mut out);
        f.fold(PartialStep { step: 1, count: 1, anoms: 0 }, &mut out);
        assert!(out.is_empty());
        f.fold(PartialStep { step: 1, count: 1, anoms: 5 }, &mut out);
        assert_eq!(out, vec![PartialStep { step: 1, count: 3, anoms: 7 }]);
        assert_eq!(f.pushed, 1);

        // A partial quorum expires once the fold moves far enough
        // ahead: it leaves the accumulator with its partial count, but
        // waits in the flush buffer instead of travelling up live.
        out.clear();
        f.fold(PartialStep { step: 2, count: 1, anoms: 1 }, &mut out);
        f.fold(
            PartialStep { step: 2 + STEP_ACC_MAX_LAG + 1, count: 3, anoms: 0 },
            &mut out,
        );
        assert_eq!(
            out,
            vec![PartialStep { step: 2 + STEP_ACC_MAX_LAG + 1, count: 3, anoms: 0 }],
            "live output carries only the completed quorum"
        );
        assert_eq!(f.take_expired(), vec![PartialStep { step: 2, count: 1, anoms: 1 }]);
        assert_eq!(f.shed, 1);

        // Stragglers past the horizon forward live without re-opening.
        out.clear();
        f.fold(PartialStep { step: 1, count: 1, anoms: 9 }, &mut out);
        assert_eq!(out, vec![PartialStep { step: 1, count: 1, anoms: 9 }]);
        assert_eq!(f.shed, 2);
        assert!(f.take_expired().is_empty());
    }

    #[test]
    fn advance_global_expires_a_silent_range_one_slot_early() {
        let mut f = StepFold::new(3);
        let mut out = Vec::new();
        f.fold(PartialStep { step: 5, count: 2, anoms: 4 }, &mut out);
        assert!(out.is_empty() && f.take_expired().is_empty());
        // Below the lag edge the stalled quorum survives…
        f.advance_global(5 + STEP_ACC_MAX_LAG - 1);
        assert!(f.take_expired().is_empty());
        // …and at it, the drain runs one slot ahead of the root's
        // strictly-below sweep, so the partial is already merged when
        // the root's horizon passes step 5.
        f.advance_global(5 + STEP_ACC_MAX_LAG);
        assert_eq!(f.take_expired(), vec![PartialStep { step: 5, count: 2, anoms: 4 }]);
        assert_eq!(f.shed, 1);
        // A lower horizon never rolls the fold backwards.
        f.advance_global(3);
        assert!(f.take_expired().is_empty());
        // Near the run start (max below the lag) nothing drains.
        let mut g = StepFold::new(3);
        g.fold(PartialStep { step: 0, count: 1, anoms: 1 }, &mut out);
        g.advance_global(STEP_ACC_MAX_LAG - 1);
        assert!(g.take_expired().is_empty(), "step 0 must survive an early flush");
        g.advance_global(STEP_ACC_MAX_LAG);
        assert_eq!(g.take_expired(), vec![PartialStep { step: 0, count: 1, anoms: 1 }]);
    }

    #[test]
    fn leaf_state_delta_and_absolute() {
        let mut leaf = LeafState::new(3, 2, 0, 2);
        let mut out = Vec::new();
        for rank in 0..2u32 {
            leaf.report(
                StepStat {
                    app: 0,
                    rank,
                    step: 1,
                    n_executions: 10,
                    n_anomalies: rank as u64,
                    ts_range: (0, 100),
                },
                &mut out,
            );
        }
        assert_eq!(out, vec![PartialStep { step: 1, count: 2, anoms: 1 }]);
        let d = leaf.delta();
        assert!(d.delta);
        assert_eq!(d.ranks.len(), 2);
        assert_eq!(d.fresh_steps.len(), 2);
        assert_eq!(d.total_anomalies, 1);
        assert_eq!(d.total_executions, 20);
        assert_eq!(d.agg_nodes.len(), 1);
        assert_eq!(d.agg_nodes[0].node, 3);
        assert_eq!(d.agg_nodes[0].folds, 2);
        assert_eq!(d.agg_nodes[0].pushed, 1);
        // Delta drained; absolute still has everything.
        let d2 = leaf.delta();
        assert!(d2.ranks.is_empty() && d2.fresh_steps.is_empty());
        let a = leaf.absolute();
        assert!(!a.delta);
        assert_eq!(a.ranks.len(), 2);
        assert_eq!(a.total_anomalies, 1);
    }
}
