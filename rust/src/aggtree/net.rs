//! TCP transport for remote aggregation-tree leaves — the `agg-node`
//! subcommand's wire (protocol kinds 13–16, continuing the PS kind
//! space; see the table in [`ps::net`](crate::ps::net)).
//!
//! A remote leaf serves exactly one rank-range behind the reactor
//! (`serve_frames`) substrate. Its **parent owns the connection** and
//! *escorts* each report and fetch through a request/reply round-trip,
//! so the report→fetch FIFO serialization — the exactly-once delivery
//! invariant's transport leg — holds across the process boundary
//! without server push:
//!
//! ```text
//! request := u32 len, u32 stream, u8 kind, payload
//!   kind 13 (agg hello):  (empty)
//!   kind 14 (agg report): app u32, rank u32, step u64, execs u64,
//!                         anoms u64, ts_lo u64, ts_hi u64
//!   kind 15 (agg fetch):  app u32, rank u32
//!   kind 16 (agg flush):  mode u8 (0 delta / 1 absolute / 2 final),
//!                         horizon u64 (tree-wide newest step)
//! reply (hello)  := node u32, depth u32, rank_lo u32, rank_hi u32
//! reply (report) := partials
//! reply (fetch)  := partials                         (empty today)
//! reply (flush)  := partials (expired by the horizon), snapshot,
//!                   fin u8 (0/1), [snapshot]
//!
//! partials := n u32, n × (step u64, count u64, anoms u64)
//! snapshot := n_ranks u32, n_ranks × (app u32, rank u32, n u64,
//!               mean f64, m2 f64, min f64, max f64, total u64),
//!             n_fresh u32, n_fresh × (app u32, rank u32, step u64,
//!               execs u64, anoms u64, ts_lo u64, ts_hi u64),
//!             anoms u64, execs u64,
//!             n_nodes u32, n_nodes × (node u32, depth u32, lo u32,
//!               hi u32, folds u64, pushed u64, shed u64),
//!             delta u8
//! ```
//!
//! The report reply carries the range quorums the report completed, so
//! partials flow upward as escort replies — the parent folds them the
//! moment the round-trip returns, on the same edge order an in-process
//! child would use. The fetch reply's partials list is empty today (a
//! fetch can't complete a quorum) but stays in the frame for a batched
//! report push later. A flush carries the tree-wide step `horizon`; its
//! reply's partials are the quorums that horizon expired from the
//! leaf's range fold, which the parent relays to the root so a stalled
//! range expires on the flat aggregator's schedule. Flush mode 2
//! (`final`) additionally returns the absolute snapshot (`fin`) that
//! `PsHandle::join` folds into the final state. An overloaded node sheds with `CTRL_BUSY` like every reactor
//! server; the parent's `Reconnector` retries the shed call in-place
//! under its bounded busy budget and only then degrades — the flush
//! proceeds without the subtree (degraded fold, logged).

use super::{LeafState, PartialStep};
use crate::ps::net::{put_stats, read_stats};
use crate::ps::{AggNodeLoad, RankSummary, StepStat, VizSnapshot};
use crate::util::net::{
    serve_frames, FrameHandler, FrameSink, NetStats, ReactorOpts, TcpServerHandle,
};
use crate::util::wire::{read_msg, write_msg, Cursor};
use anyhow::{bail, Context, Result};
use std::net::TcpStream;
use std::sync::{Arc, Mutex};

pub(crate) const KIND_AGG_HELLO: u8 = 13;
pub(crate) const KIND_AGG_REPORT: u8 = 14;
pub(crate) const KIND_AGG_FETCH: u8 = 15;
pub(crate) const KIND_AGG_FLUSH: u8 = 16;

/// Flush modes (the wire byte and the in-process `FlushKind` mapping).
pub(crate) const FLUSH_DELTA: u8 = 0;
pub(crate) const FLUSH_ABSOLUTE: u8 = 1;
pub(crate) const FLUSH_FINAL: u8 = 2;

fn put_partials(buf: &mut Vec<u8>, ps: &[PartialStep]) {
    buf.extend_from_slice(&(ps.len() as u32).to_le_bytes());
    for p in ps {
        buf.extend_from_slice(&p.step.to_le_bytes());
        buf.extend_from_slice(&p.count.to_le_bytes());
        buf.extend_from_slice(&p.anoms.to_le_bytes());
    }
}

fn read_partials(c: &mut Cursor) -> Result<Vec<PartialStep>> {
    let n = c.u32()? as usize;
    // Count is peer-supplied: cap the pre-allocation.
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        out.push(PartialStep { step: c.u64()?, count: c.u64()?, anoms: c.u64()? });
    }
    Ok(out)
}

/// Serialize the leaf-plane subset of a [`VizSnapshot`]: rank summaries,
/// fresh steps, totals, node loads, and the delta flag. A leaf never
/// carries functions/events/shard-loads/placement, so those fields stay
/// off the wire.
fn put_snapshot(buf: &mut Vec<u8>, s: &VizSnapshot) {
    buf.extend_from_slice(&(s.ranks.len() as u32).to_le_bytes());
    for r in &s.ranks {
        buf.extend_from_slice(&r.app.to_le_bytes());
        put_stats(buf, r.rank, &r.step_counts);
        buf.extend_from_slice(&r.total_anomalies.to_le_bytes());
    }
    buf.extend_from_slice(&(s.fresh_steps.len() as u32).to_le_bytes());
    for st in &s.fresh_steps {
        put_step_stat(buf, st);
    }
    buf.extend_from_slice(&s.total_anomalies.to_le_bytes());
    buf.extend_from_slice(&s.total_executions.to_le_bytes());
    buf.extend_from_slice(&(s.agg_nodes.len() as u32).to_le_bytes());
    for n in &s.agg_nodes {
        buf.extend_from_slice(&n.node.to_le_bytes());
        buf.extend_from_slice(&n.depth.to_le_bytes());
        buf.extend_from_slice(&n.rank_lo.to_le_bytes());
        buf.extend_from_slice(&n.rank_hi.to_le_bytes());
        buf.extend_from_slice(&n.folds.to_le_bytes());
        buf.extend_from_slice(&n.pushed.to_le_bytes());
        buf.extend_from_slice(&n.shed.to_le_bytes());
    }
    buf.push(if s.delta { 1 } else { 0 });
}

fn read_snapshot(c: &mut Cursor) -> Result<VizSnapshot> {
    let n_ranks = c.u32()? as usize;
    let mut ranks = Vec::with_capacity(n_ranks.min(4096));
    for _ in 0..n_ranks {
        let app = c.u32()?;
        let (rank, step_counts) = read_stats(c)?;
        let total_anomalies = c.u64()?;
        ranks.push(RankSummary { app, rank, step_counts, total_anomalies });
    }
    let n_fresh = c.u32()? as usize;
    let mut fresh_steps = Vec::with_capacity(n_fresh.min(4096));
    for _ in 0..n_fresh {
        fresh_steps.push(read_step_stat(c)?);
    }
    let total_anomalies = c.u64()?;
    let total_executions = c.u64()?;
    let n_nodes = c.u32()? as usize;
    let mut agg_nodes = Vec::with_capacity(n_nodes.min(4096));
    for _ in 0..n_nodes {
        agg_nodes.push(AggNodeLoad {
            node: c.u32()?,
            depth: c.u32()?,
            rank_lo: c.u32()?,
            rank_hi: c.u32()?,
            folds: c.u64()?,
            pushed: c.u64()?,
            shed: c.u64()?,
        });
    }
    let delta = c.u8()? != 0;
    Ok(VizSnapshot {
        ranks,
        fresh_steps,
        total_anomalies,
        total_executions,
        agg_nodes,
        delta,
        ..VizSnapshot::default()
    })
}

fn put_step_stat(buf: &mut Vec<u8>, st: &StepStat) {
    buf.extend_from_slice(&st.app.to_le_bytes());
    buf.extend_from_slice(&st.rank.to_le_bytes());
    buf.extend_from_slice(&st.step.to_le_bytes());
    buf.extend_from_slice(&st.n_executions.to_le_bytes());
    buf.extend_from_slice(&st.n_anomalies.to_le_bytes());
    buf.extend_from_slice(&st.ts_range.0.to_le_bytes());
    buf.extend_from_slice(&st.ts_range.1.to_le_bytes());
}

fn read_step_stat(c: &mut Cursor) -> Result<StepStat> {
    Ok(StepStat {
        app: c.u32()?,
        rank: c.u32()?,
        step: c.u64()?,
        n_executions: c.u64()?,
        n_anomalies: c.u64()?,
        ts_range: (c.u64()?, c.u64()?),
    })
}

/// A remote `agg-node` process: one [`LeafState`] behind the reactor.
pub struct AggNodeServer {
    inner: TcpServerHandle,
}

impl AggNodeServer {
    /// Bind and serve leaf `node` (depth `depth`) owning ranks
    /// `[rank_lo, rank_hi)`.
    pub fn start(
        addr: &str,
        node: u32,
        depth: u32,
        rank_lo: u32,
        rank_hi: u32,
        opts: ReactorOpts,
    ) -> Result<AggNodeServer> {
        let state = Arc::new(Mutex::new(LeafState::new(node, depth, rank_lo, rank_hi)));
        let inner = serve_frames("chimbuko-agg-node", addr, opts, NetStats::new(), move || {
            AggNodeHandler { state: state.clone() }
        })?;
        Ok(AggNodeServer { inner })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.inner.addr()
    }

    /// Transport counters (accepted/shed/queue depth…) for this node.
    pub fn net_stats(&self) -> Arc<NetStats> {
        self.inner.stats().clone()
    }

    pub fn stop(&mut self) {
        self.inner.stop();
    }
}

struct AggNodeHandler {
    state: Arc<Mutex<LeafState>>,
}

impl FrameHandler for AggNodeHandler {
    fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool {
        let mut c = Cursor::new(payload);
        let kind = match c.u8() {
            Ok(k) => k,
            Err(_) => return false,
        };
        let mut reply = Vec::new();
        let mut state = self.state.lock().expect("agg-node state lock");
        match kind {
            KIND_AGG_HELLO => {
                let load = state.load();
                reply.extend_from_slice(&load.node.to_le_bytes());
                reply.extend_from_slice(&load.depth.to_le_bytes());
                reply.extend_from_slice(&load.rank_lo.to_le_bytes());
                reply.extend_from_slice(&load.rank_hi.to_le_bytes());
            }
            KIND_AGG_REPORT => {
                let stat = match read_step_stat(&mut c) {
                    Ok(s) => s,
                    Err(_) => return false,
                };
                let mut partials = Vec::new();
                state.report(stat, &mut partials);
                put_partials(&mut reply, &partials);
            }
            KIND_AGG_FETCH => {
                // The fetch is an ordering escort: it completes nothing,
                // but replying *after* every earlier report's reply is
                // what serializes it behind them.
                if c.u32().is_err() || c.u32().is_err() {
                    return false;
                }
                put_partials(&mut reply, &[]);
            }
            KIND_AGG_FLUSH => {
                let mode = match c.u8() {
                    Ok(m) => m,
                    Err(_) => return false,
                };
                // Parents predating the horizon field don't send one;
                // treat that as "no reconciliation", not a bad frame.
                let horizon = c.u64().unwrap_or(0);
                put_partials(&mut reply, &state.reconcile_horizon(horizon));
                match mode {
                    FLUSH_DELTA => {
                        put_snapshot(&mut reply, &state.delta());
                        reply.push(0);
                    }
                    FLUSH_ABSOLUTE => {
                        put_snapshot(&mut reply, &state.absolute());
                        reply.push(0);
                    }
                    FLUSH_FINAL => {
                        put_snapshot(&mut reply, &state.delta());
                        reply.push(1);
                        put_snapshot(&mut reply, &state.absolute());
                    }
                    _ => return false,
                }
            }
            _ => return false,
        }
        out.send(stream, &reply);
        true
    }
}

/// Parent-side connection to one remote leaf. Single-stream (the parent
/// thread is the only caller), so plain `write_msg`/`read_msg` framing.
pub struct TreeWire {
    stream: TcpStream,
}

impl TreeWire {
    /// Dial and verify the topology hello: the node at `addr` must be
    /// leaf `node` owning `[rank_lo, rank_hi)` — a mis-wired endpoint
    /// list fails here, at spawn, not as silently mis-folded stats.
    pub fn connect(addr: &str, node: u32, rank_lo: u32, rank_hi: u32) -> Result<TreeWire> {
        let stream = TcpStream::connect(addr).with_context(|| format!("agg-node at {addr}"))?;
        stream.set_nodelay(true).ok();
        let mut wire = TreeWire { stream };
        let reply = wire.call(&[KIND_AGG_HELLO])?;
        let mut c = Cursor::new(&reply);
        let (n, _depth, lo, hi) = (c.u32()?, c.u32()?, c.u32()?, c.u32()?);
        if n != node || lo != rank_lo || hi != rank_hi {
            bail!(
                "agg-node at {addr} is node {n} [{lo},{hi}), expected node {node} \
                 [{rank_lo},{rank_hi})"
            );
        }
        Ok(wire)
    }

    fn call(&mut self, req: &[u8]) -> Result<Vec<u8>> {
        write_msg(&mut self.stream, req)?;
        read_msg(&mut self.stream)?.context("agg-node closed the connection")
    }

    /// Escort one rank report; returns the range quorums it completed.
    pub(crate) fn report(&mut self, stat: &StepStat) -> Result<Vec<PartialStep>> {
        let mut req = vec![KIND_AGG_REPORT];
        put_step_stat(&mut req, stat);
        let reply = self.call(&req)?;
        read_partials(&mut Cursor::new(&reply))
    }

    /// Escort one event fetch (ordering barrier; completes nothing).
    pub(crate) fn fetch(&mut self, app: u32, rank: u32) -> Result<Vec<PartialStep>> {
        let mut req = vec![KIND_AGG_FETCH];
        req.extend_from_slice(&app.to_le_bytes());
        req.extend_from_slice(&rank.to_le_bytes());
        let reply = self.call(&req)?;
        read_partials(&mut Cursor::new(&reply))
    }

    /// Run one flush round-trip at the tree-wide step `horizon`;
    /// returns `(expired partials, snapshot, fin)`.
    pub(crate) fn flush(
        &mut self,
        mode: u8,
        horizon: u64,
    ) -> Result<(Vec<PartialStep>, VizSnapshot, Option<VizSnapshot>)> {
        let mut req = vec![KIND_AGG_FLUSH, mode];
        req.extend_from_slice(&horizon.to_le_bytes());
        let reply = self.call(&req)?;
        let mut c = Cursor::new(&reply);
        let partials = read_partials(&mut c)?;
        let snap = read_snapshot(&mut c)?;
        let fin = if c.u8()? != 0 { Some(read_snapshot(&mut c)?) } else { None };
        Ok((partials, snap, fin))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stat(rank: u32, step: u64, anoms: u64) -> StepStat {
        StepStat {
            app: 0,
            rank,
            step,
            n_executions: 10,
            n_anomalies: anoms,
            ts_range: (step * 100, step * 100 + 99),
        }
    }

    #[test]
    fn snapshot_codec_roundtrip() {
        let mut leaf = LeafState::new(5, 2, 0, 2);
        let mut out = Vec::new();
        leaf.report(stat(0, 1, 3), &mut out);
        leaf.report(stat(1, 1, 0), &mut out);
        let snap = leaf.absolute();
        let mut buf = Vec::new();
        put_snapshot(&mut buf, &snap);
        let got = read_snapshot(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(got.ranks, snap.ranks);
        assert_eq!(got.fresh_steps, snap.fresh_steps);
        assert_eq!(got.total_anomalies, snap.total_anomalies);
        assert_eq!(got.total_executions, snap.total_executions);
        assert_eq!(got.agg_nodes, snap.agg_nodes);
        assert_eq!(got.delta, snap.delta);
        // Truncated wire refused, not mis-read.
        assert!(read_snapshot(&mut Cursor::new(&buf[..buf.len() - 1])).is_err());
    }

    #[test]
    fn agg_node_serves_reports_fetches_and_flushes() {
        let srv =
            AggNodeServer::start("127.0.0.1:0", 3, 1, 0, 2, ReactorOpts::default()).unwrap();
        let addr = srv.addr().to_string();
        // Hello verification: wrong expectations must refuse.
        assert!(TreeWire::connect(&addr, 4, 0, 2).is_err());
        assert!(TreeWire::connect(&addr, 3, 0, 3).is_err());
        let mut w = TreeWire::connect(&addr, 3, 0, 2).unwrap();
        assert!(w.report(&stat(0, 1, 2)).unwrap().is_empty());
        assert_eq!(
            w.report(&stat(1, 1, 1)).unwrap(),
            vec![PartialStep { step: 1, count: 2, anoms: 3 }],
            "second rank completes the range quorum"
        );
        assert!(w.fetch(0, 1).unwrap().is_empty());
        let (ps, delta, fin) = w.flush(FLUSH_DELTA, 0).unwrap();
        assert!(ps.is_empty() && fin.is_none());
        assert!(delta.delta);
        assert_eq!(delta.ranks.len(), 2);
        assert_eq!(delta.total_anomalies, 3);
        // A pending half-quorum expires when the flush's horizon says
        // the rest of the tree has moved past it, and rides the reply.
        use crate::ps::STEP_ACC_MAX_LAG;
        assert!(w.report(&stat(0, 2, 4)).unwrap().is_empty(), "half a range quorum pends");
        let (expired, _, _) = w.flush(FLUSH_DELTA, 2 + STEP_ACC_MAX_LAG).unwrap();
        assert_eq!(expired, vec![PartialStep { step: 2, count: 1, anoms: 4 }]);
        // Delta drained; a final flush still carries the absolute state.
        let (_, delta2, fin2) = w.flush(FLUSH_FINAL, 0).unwrap();
        assert!(delta2.ranks.is_empty(), "second delta is empty");
        let fin2 = fin2.expect("final flush carries the absolute snapshot");
        assert_eq!(fin2.ranks.len(), 2);
        assert_eq!(fin2.agg_nodes.len(), 1);
        assert_eq!(fin2.agg_nodes[0].node, 3);
        assert_eq!(fin2.agg_nodes[0].folds, 3);
        drop(srv);
    }
}
