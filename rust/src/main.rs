//! `chimbuko` — CLI for the workflow-level trace-analysis pipeline.
//!
//! ```text
//! chimbuko run      [--config f] [--ranks N] [--steps N] [--backend rust|xla]
//!                   [--ps-shards N] [--ps-endpoints a,b,…] [--ps-conn-pool N]
//!                   [--rebalance-interval-ms N] [--rebalance-max-ratio X]
//!                   [--rebalance-min-merges N] [--out dir]
//!                   [--provdb host:port] [--unfiltered] [--serve]
//! chimbuko gen      [--ranks N] [--steps N] [--out trace.bp] [--unfiltered]
//! chimbuko replay   --dir <out_dir>        re-index a stored run, print stats
//! chimbuko serve    --dir <out_dir> | --provdb host:port  [--addr host:port]
//!                   viz server over a stored run or a live provDB service
//! chimbuko exp      <fig7|fig8|fig9|viz|case|chaos> [--fast]  paper experiments
//! chimbuko compare  --a <dir> --b <dir>    cross-run provenance mining
//! chimbuko ps-server [--addr host:port] [--shards N] [--ranks N]
//!                   [--endpoints a,b,…] [--conn-pool N] [--reactor-threads N]
//!                   [--publish-interval-ms N] [--rebalance-interval-ms N]
//!                   [--rebalance-max-ratio X] [--rebalance-min-merges N]
//!                   standalone TCP parameter server (front-end when
//!                   --endpoints lists ps-shard-server addresses)
//! chimbuko ps-shard-server --shard-id I --shards N [--addr host:port]
//!                   [--reactor-threads N]
//!                   one stat shard of a multi-process parameter server
//! chimbuko agg-node --node I --rank-lo L --rank-hi H [--depth D]
//!                   [--addr host:port] [--reactor-threads N]
//!                   one leaf of the hierarchical aggregation tree (a
//!                   parent configured with `ps.agg_endpoints` folds it)
//! chimbuko provdb-server [--config f] [--addr host:port] [--shards N]
//!                   [--dir d] [--max-records-per-rank N]
//!                   [--segment-records N] [--retain-window-us N]
//!                   [--log-format binary|jsonl] [--reactor-threads N]
//!                   standalone provenance database (binary segment log by
//!                   default; jsonl is the classic-layout escape hatch;
//!                   --config seeds the [provdb] knobs, flags override)
//! chimbuko analyze  --bp trace.bp [--out dir] [--algorithm hbos]  offline re-analysis
//! chimbuko probe    check <file>           compile a probe file, print a summary
//!                   install <file> --provdb host:port   install its probes
//!                   list --provdb host:port             installed probes + counters
//!                   remove <name> --provdb host:port
//! chimbuko version
//! ```
//!
//! `chimbuko run` also accepts `--probe <file>` (install the file's probes
//! into the provDB service at run start; requires `--provdb`) — see
//! `rust/docs/probe.md` for the probe language.
//!
//! `-v` / `-vv` on any command raise the execution-trace log level to
//! debug / trace (`CHIMBUKO_LOG` sets the baseline, `CHIMBUKO_LOG_FILE`
//! tees the stream to a file). `CHIMBUKO_CHAOS` installs a deterministic
//! fault plan in any server process — see `rust/docs/chaos.md`.

use chimbuko::cli::Args;
use chimbuko::config::{Config, DetectorBackend};
use chimbuko::coordinator::{run, Mode, Workflow};
use chimbuko::provdb::{ProvDbTcpServer, Retention};
use chimbuko::provenance::ProvDb;
use chimbuko::trace::RankTracer;
use chimbuko::util::fmt_bytes;
use chimbuko::viz::{http::VizServer, ProvSource, VizState};
use std::path::Path;
use std::sync::{Arc, RwLock};

fn main() {
    let args = Args::from_env(true);
    // `-v` / `-vv` raise the log level before anything else runs (the
    // `CHIMBUKO_LOG` env still sets the baseline when neither is given).
    match args.verbosity() {
        2 => chimbuko::util::log::set_level(chimbuko::util::log::Level::Trace),
        1 => chimbuko::util::log::set_level(chimbuko::util::log::Level::Debug),
        _ => {}
    }
    let code = match args.subcommand.as_deref() {
        Some("run") => cmd_run(&args),
        Some("gen") => cmd_gen(&args),
        Some("replay") => cmd_replay(&args),
        Some("serve") => cmd_serve(&args),
        Some("exp") => cmd_exp(&args),
        Some("compare") => cmd_compare(&args),
        Some("ps-server") => cmd_ps_server(&args),
        Some("ps-shard-server") => cmd_ps_shard_server(&args),
        Some("agg-node") => cmd_agg_node(&args),
        Some("provdb-server") => cmd_provdb_server(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("probe") => cmd_probe(&args),
        Some("version") => {
            println!("chimbuko {}", chimbuko::VERSION);
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: chimbuko <run|gen|replay|serve|exp|compare|ps-server|ps-shard-server|agg-node|provdb-server|analyze|probe|version> [options]\n\
                 see `rust/src/main.rs` header or README for options"
            );
            std::process::exit(2);
        }
    }
    .map(|_| 0)
    .unwrap_or_else(|e| {
        eprintln!("error: {e:#}");
        1
    });
    std::process::exit(code);
}

/// Build a Config from `--config` + CLI overrides.
fn config_of(args: &Args) -> anyhow::Result<Config> {
    let mut cfg = match args.get("config") {
        Some(path) => Config::from_file(Path::new(path))?,
        None => Config::default(),
    };
    if let Some(v) = args.get("ranks") {
        cfg.apply("ranks", v)?;
    }
    if let Some(v) = args.get("steps") {
        cfg.apply("steps", v)?;
    }
    if let Some(v) = args.get("backend") {
        cfg.apply("backend", v)?;
    }
    if let Some(v) = args.get("alpha") {
        cfg.apply("alpha", v)?;
    }
    if let Some(v) = args.get("seed") {
        cfg.apply("seed", v)?;
    }
    if let Some(v) = args.get("out") {
        cfg.out_dir = v.to_string();
    }
    if let Some(v) = args.get("calls-per-step") {
        cfg.apply("calls_per_step", v)?;
    }
    if let Some(v) = args.get("ps-shards") {
        cfg.apply("ps.shards", v)?;
    }
    if let Some(v) = args.get("ps-endpoints") {
        cfg.apply("ps.endpoints", v)?;
    }
    if let Some(v) = args.get("ps-conn-pool") {
        cfg.apply("ps.conn_pool", v)?;
    }
    if let Some(v) = args.get("rebalance-interval-ms") {
        cfg.apply("ps.rebalance_interval_ms", v)?;
    }
    if let Some(v) = args.get("rebalance-max-ratio") {
        cfg.apply("ps.rebalance_max_ratio", v)?;
    }
    if let Some(v) = args.get("rebalance-min-merges") {
        cfg.apply("ps.rebalance_min_merges", v)?;
    }
    if let Some(v) = args.get("publish-interval-ms") {
        cfg.apply("ps.publish_interval_ms", v)?;
    }
    if let Some(v) = args.get("provdb") {
        cfg.apply("provdb.addr", v)?;
    }
    if let Some(v) = args.get("provdb-batch") {
        cfg.apply("provdb.batch", v)?;
    }
    if let Some(v) = args.get("probe") {
        cfg.apply("probe.file", v)?;
    }
    if args.flag("unfiltered") {
        cfg.filtered = false;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn cmd_run(args: &Args) -> anyhow::Result<()> {
    let cfg = config_of(args)?;
    let workflow = Workflow::nwchem(&cfg);
    println!(
        "chimbuko run: {} ranks ({} MD / {} analysis), {} steps, backend={}, {}",
        cfg.ranks,
        workflow.ranks_of_app(0),
        workflow.ranks_of_app(1),
        cfg.steps,
        cfg.backend.name(),
        if cfg.filtered { "filtered" } else { "unfiltered" },
    );
    if cfg.backend == DetectorBackend::Xla {
        println!("  (AOT artifacts from {}/)", cfg.artifacts_dir);
    }
    if !cfg.probe_file.is_empty() {
        let n = install_probe_file(&cfg.probe_file, &cfg.provdb_addr)?;
        println!("  installed {} probe(s) from {} into {}", n, cfg.probe_file, cfg.provdb_addr);
    }
    let report = run(&cfg, &workflow, Mode::TauChimbuko)?;
    println!("{}", report.to_json().to_pretty());
    println!(
        "\nsummary: {} events → {} executions, {} anomalies, {} kept ({} reduced output) in {:.2}s",
        report.total_events,
        report.total_execs,
        report.total_anomalies,
        report.total_kept,
        fmt_bytes(report.reduced_bytes),
        report.wall_seconds
    );

    if args.flag("serve") {
        let state = if !cfg.provdb_addr.is_empty() {
            // The run's provenance lives in the provDB service — proxy
            // detail queries there instead of loading local files.
            let mut s = VizState::from_run(
                &report.snapshots,
                report.snapshot.clone(),
                ProvDb::in_memory(),
                workflow.registries.clone(),
            );
            s.db = ProvSource::remote(&cfg.provdb_addr)?;
            s
        } else {
            let dir = report
                .out_dir
                .clone()
                .ok_or_else(|| anyhow::anyhow!("--serve needs --out <dir> or --provdb"))?;
            let db = ProvDb::load(&dir)?;
            VizState::from_run(
                &report.snapshots,
                report.snapshot.clone(),
                db,
                workflow.registries.clone(),
            )
        };
        let server = VizServer::start(
            &args.str_opt("addr", "127.0.0.1:8787"),
            Arc::new(RwLock::new(state)),
        )?;
        println!("viz server on http://{} — Ctrl-C to stop", server.addr());
        loop {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
    Ok(())
}

fn cmd_gen(args: &Args) -> anyhow::Result<()> {
    let cfg = config_of(args)?;
    let out = args.str_opt("out", "trace.bp");
    let workflow = Workflow::nwchem(&cfg);
    let mut writer = chimbuko::adios::BpWriter::create(Path::new(&out))?;
    let mut rng = chimbuko::util::rng::Rng::new(cfg.seed);
    for a in &workflow.assignments {
        let mut tracer = RankTracer::new(
            workflow.grammars[a.app as usize].clone(),
            a.app,
            a.app_rank,
            workflow.app_world(a.app),
            !cfg.filtered,
            rng.fork(a.rank as u64),
        );
        for _ in 0..cfg.steps {
            writer.put_step(&tracer.step())?;
        }
    }
    writer.flush()?;
    println!(
        "wrote {} frames / {} events / {} to {}",
        writer.frames_written(),
        writer.events_written(),
        fmt_bytes(writer.bytes_written()),
        out
    );
    Ok(())
}

fn cmd_replay(args: &Args) -> anyhow::Result<()> {
    let dir = args
        .get("dir")
        .ok_or_else(|| anyhow::anyhow!("replay needs --dir <out_dir>"))?;
    let db = ProvDb::load(Path::new(dir))?;
    let meta = ProvDb::load_metadata(Path::new(dir)).ok();
    println!(
        "replayed {}: {} provenance records, {} anomalies, {}",
        dir,
        db.len(),
        db.anomaly_count(),
        fmt_bytes(db.bytes_written())
    );
    if let Some(m) = meta {
        if let Some(run_id) = m.get("run_id").and_then(|v| v.as_str()) {
            println!("run_id: {run_id}");
        }
    }
    // Top anomalies.
    let top = db.query(&chimbuko::provenance::ProvQuery {
        anomalies_only: true,
        order_by_score: true,
        limit: Some(10),
        ..Default::default()
    });
    println!("top anomalies:");
    for r in top {
        println!(
            "  {:>8.1}σ  {:<16} rank {:>4} step {:>4}  {:>10}µs",
            r.score, r.func, r.rank, r.step, r.inclusive_us
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    // Registries from metadata are display-only; rebuild defaults.
    let regs = chimbuko::trace::nwchem::workflow_registries();
    let mut state = VizState::new(regs);
    if let Some(addr) = args.get("provdb") {
        // Live mode: proxy detail queries to the provDB service.
        state.db = ProvSource::remote(addr)?;
    } else {
        let dir = args
            .get("dir")
            .ok_or_else(|| anyhow::anyhow!("serve needs --dir <out_dir> or --provdb <addr>"))?;
        let db = ProvDb::load(Path::new(dir))?;
        let meta = ProvDb::load_metadata(Path::new(dir)).ok();
        state.db = ProvSource::local_with_meta(db, meta);
    }
    let server = VizServer::start(
        &args.str_opt("addr", "127.0.0.1:8787"),
        Arc::new(RwLock::new(state)),
    )?;
    println!("viz server on http://{} — Ctrl-C to stop", server.addr());
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Offline mode: re-analyze a stored BP trace (paper §II-B).
fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let bp = args.get("bp").ok_or_else(|| anyhow::anyhow!("analyze needs --bp <trace.bp>"))?;
    let mut cfg = config_of(args)?;
    if args.get("out").is_none() {
        cfg.out_dir = String::new(); // in-memory unless asked
    }
    if let Some(a) = args.get("algorithm") {
        cfg.apply("algorithm", a)?;
    }
    let rep = chimbuko::coordinator::analyze_bp(Path::new(bp), &cfg)?;
    print!("{}", rep.render());
    Ok(())
}

/// Install every probe in `path` into the provDB service at `addr`.
fn install_probe_file(path: &str, addr: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(!addr.is_empty(), "--probe requires --provdb (or provdb.addr in the config)");
    let source = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading probe file {path}: {e}"))?;
    let probes = chimbuko::probe::Probe::compile_all(&source)
        .map_err(|e| anyhow::anyhow!("compiling probe file {path}: {e:#}"))?;
    let mut client = chimbuko::provdb::ProvClient::connect(addr)?;
    for p in &probes {
        client.install_probe(p)?;
    }
    Ok(probes.len())
}

/// `chimbuko probe <check|install|list|remove>` — compile probe files and
/// manage the probes installed in a running provDB service.
fn cmd_probe(args: &Args) -> anyhow::Result<()> {
    let usage = "usage: chimbuko probe <check <file> | install <file> --provdb host:port | list --provdb host:port | remove <name> --provdb host:port>";
    let pos = args.positionals();
    match pos.first().map(|s| s.as_str()) {
        Some("check") => {
            let path = pos.get(1).ok_or_else(|| anyhow::anyhow!("probe check needs a file"))?;
            let source = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("reading probe file {path}: {e}"))?;
            let probes = chimbuko::probe::Probe::compile_all(&source)
                .map_err(|e| anyhow::anyhow!("{path}: {e:#}"))?;
            println!("{path}: {} probe(s) ok", probes.len());
            for p in &probes {
                println!("  {}", p.describe());
            }
            Ok(())
        }
        Some("install") => {
            let path = pos.get(1).ok_or_else(|| anyhow::anyhow!("probe install needs a file"))?;
            let addr = args.str_opt("provdb", "");
            let n = install_probe_file(path, &addr)?;
            println!("installed {n} probe(s) from {path} into {addr}");
            Ok(())
        }
        Some("list") => {
            let addr = args.str_opt("provdb", "");
            anyhow::ensure!(!addr.is_empty(), "probe list needs --provdb host:port");
            let mut client = chimbuko::provdb::ProvClient::connect(&addr)?;
            let infos = client.list_probes()?;
            println!("{} probe(s) installed at {addr}", infos.len());
            for i in &infos {
                println!(
                    "  {}: matches={} shed={} pushed_records={} pushed_bytes={}\n    {}",
                    i.name, i.matches, i.shed, i.pushed_records, i.pushed_bytes, i.source
                );
            }
            Ok(())
        }
        Some("remove") => {
            let name = pos.get(1).ok_or_else(|| anyhow::anyhow!("probe remove needs a name"))?;
            let addr = args.str_opt("provdb", "");
            anyhow::ensure!(!addr.is_empty(), "probe remove needs --provdb host:port");
            let mut client = chimbuko::provdb::ProvClient::connect(&addr)?;
            let existed = client.remove_probe(name)?;
            println!("{}", if existed { "removed" } else { "no such probe" });
            Ok(())
        }
        _ => anyhow::bail!("{usage}"),
    }
}

/// Standalone parameter server reachable over TCP (`ps::net` protocol) —
/// the cross-process deployment shape of the paper's architecture.
///
/// `--ranks` must equal the number of ranks that will send per-step
/// reports: it is the quorum that completes a step's workflow-wide
/// anomaly total. Too high and steps never complete on time (their
/// accumulators expire by step distance with partial totals, so
/// global-event detection degrades rather than the server leaking); too
/// low and steps complete early on partial totals.
fn cmd_ps_server(args: &Args) -> anyhow::Result<()> {
    use std::io::Write;
    chimbuko::util::fault::init_from_env()?;
    let addr = args.str_opt("addr", "127.0.0.1:5559");
    let endpoints: Vec<String> = args
        .str_opt("endpoints", "")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let shards = if endpoints.is_empty() { args.usize_opt("shards", 4) } else { endpoints.len() };
    let (client, _handle) = chimbuko::ps::spawn_with(chimbuko::ps::PsOpts {
        shards,
        endpoints: endpoints.clone(),
        conn_pool: args.usize_opt("conn-pool", 4),
        viz_tx: None,
        publish_every: args.usize_opt("publish-every", 64),
        publish_interval_ms: args.u64_opt("publish-interval-ms", 0),
        reports_per_step: args.usize_opt("ranks", 64),
        rebalance_interval_ms: args.u64_opt("rebalance-interval-ms", 0),
        rebalance_max_ratio: args.f64_opt("rebalance-max-ratio", 1.5),
        rebalance_min_merges: args.u64_opt("rebalance-min-merges", 256),
        agg_fanout: args.usize_opt("agg-fanout", 0),
        agg_endpoints: Vec::new(),
        trigger_probes: Vec::new(),
        trigger_tx: None,
    })?;
    let net_opts = chimbuko::util::net::ReactorOpts {
        threads: args.usize_opt("reactor-threads", 2),
        ..Default::default()
    };
    let server = chimbuko::ps::net::PsTcpServer::start_with_opts(
        &addr,
        client,
        endpoints.clone(),
        net_opts,
    )?;
    println!(
        "parameter server on {} ({} shards{}) — Ctrl-C to stop",
        server.addr(),
        shards,
        if endpoints.is_empty() {
            String::new()
        } else {
            format!(", endpoints {}", endpoints.join(","))
        },
    );
    // Line-buffered only on a terminal: flush so a parent process
    // scraping the address (e2e smoke test) sees it immediately.
    std::io::stdout().flush().ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// One stat shard of a multi-process parameter server: owns the
/// `shard_of(app, fid, N) == I` partition, serves shard-sync frames at
/// its own endpoint, and mirrors the aggregator's event version (pushed
/// by the front-end) into its sync replies. Pair with
/// `ps-server --endpoints` listing every shard's address.
fn cmd_ps_shard_server(args: &Args) -> anyhow::Result<()> {
    use std::io::Write;
    chimbuko::util::fault::init_from_env()?;
    let addr = args.str_opt("addr", "127.0.0.1:5561");
    let shard_id = args.usize_opt("shard-id", 0);
    let shards = args.usize_opt("shards", 1);
    let net_opts = chimbuko::util::net::ReactorOpts {
        threads: args.usize_opt("reactor-threads", 2),
        ..Default::default()
    };
    let server = chimbuko::ps::net::PsShardTcpServer::spawn_standalone_with_opts(
        &addr,
        shard_id as u32,
        shards as u32,
        net_opts,
    )?;
    println!(
        "ps-shard-server shard {}/{} listening on {} — Ctrl-C to stop",
        shard_id,
        shards,
        server.addr()
    );
    std::io::stdout().flush().ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// One leaf node of the hierarchical aggregation tree (`aggtree::net`
/// protocol, kinds 13–16): owns the `[rank_lo, rank_hi)` slice of the
/// step timeline and answers report / fetch / flush frames from its
/// in-process parent. Point a `ps.agg_endpoints` slot at its address.
fn cmd_agg_node(args: &Args) -> anyhow::Result<()> {
    use std::io::Write;
    chimbuko::util::fault::init_from_env()?;
    let addr = args.str_opt("addr", "127.0.0.1:5571");
    let node = args.usize_opt("node", 1) as u32;
    let depth = args.usize_opt("depth", 1) as u32;
    let rank_lo = args.usize_opt("rank-lo", 0) as u32;
    let rank_hi = args.usize_opt("rank-hi", 1) as u32;
    anyhow::ensure!(rank_lo < rank_hi, "--rank-lo must be < --rank-hi");
    let net_opts = chimbuko::util::net::ReactorOpts {
        threads: args.usize_opt("reactor-threads", 2),
        ..Default::default()
    };
    let server =
        chimbuko::aggtree::net::AggNodeServer::start(&addr, node, depth, rank_lo, rank_hi, net_opts)?;
    println!(
        "agg-node {} ranks [{},{}) listening on {} — Ctrl-C to stop",
        node,
        rank_lo,
        rank_hi,
        server.addr()
    );
    std::io::stdout().flush().ok();
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Standalone provenance database service (`provdb::net` protocol): AD
/// ranks of a `chimbuko run --provdb <addr>` write to it, `chimbuko
/// serve --provdb <addr>` queries it — the paper's dedicated provenance
/// store, decoupled from the analysis ranks. `--config` seeds the
/// `[provdb]` knobs (shards, max_records_per_rank, segment_records,
/// retain_window_us, log_format); CLI flags override.
fn cmd_provdb_server(args: &Args) -> anyhow::Result<()> {
    chimbuko::util::fault::init_from_env()?;
    let cfg = config_of(args)?;
    let addr = args.str_opt("addr", "127.0.0.1:5560");
    let shards = args.usize_opt("shards", cfg.provdb_shards);
    let retention =
        Retention::from_knob(args.usize_opt("max-records-per-rank", cfg.provdb_max_per_rank))
            .with_segment_knob(args.usize_opt("segment-records", cfg.provdb_segment_records))
            .with_window_knob(args.u64_opt("retain-window-us", cfg.provdb_retain_window_us));
    let dir = args.get("dir").map(std::path::PathBuf::from);
    let format = match args.get("log-format") {
        Some(v) => chimbuko::provenance::RecordFormat::parse(v)?,
        None => cfg.provdb_log_format,
    };
    let (store, _handle) =
        chimbuko::provdb::spawn_store_fmt(dir.as_deref(), shards, retention, format)?;
    // [net] knobs from --config size the reactor; the flag overrides.
    let mut net_opts = cfg.net_opts();
    if let Some(v) = args.get("reactor-threads") {
        net_opts.threads = v.parse::<usize>()?.max(1);
    }
    let server = ProvDbTcpServer::start_with_opts(&addr, store, net_opts)?;
    println!(
        "provenance database on {} ({} shards, {}, {}, {} log) — Ctrl-C to stop",
        server.addr(),
        shards,
        match &dir {
            Some(d) => format!("log dir {}", d.display()),
            None => "memory only".to_string(),
        },
        if retention.max_records_per_rank == usize::MAX {
            "unbounded".to_string()
        } else {
            format!("≤{} records/rank", retention.max_records_per_rank)
        },
        format.name(),
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let a = args.get("a").ok_or_else(|| anyhow::anyhow!("compare needs --a <dir>"))?;
    let b = args.get("b").ok_or_else(|| anyhow::anyhow!("compare needs --b <dir>"))?;
    let db_a = ProvDb::load(Path::new(a))?;
    let db_b = ProvDb::load(Path::new(b))?;
    let cmp = chimbuko::provenance::compare(a, &db_a, b, &db_b);
    print!("{}", cmp.render());
    if args.flag("json") {
        println!("{}", cmp.to_json().to_pretty());
    }
    Ok(())
}

fn cmd_exp(args: &Args) -> anyhow::Result<()> {
    let which = args
        .positionals()
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let fast = args.flag("fast");
    let run_fig7 = || -> anyhow::Result<()> {
        let scales: Vec<usize> = args
            .u64_list("scales", &[10, 20, 40, 60, 80, 100])
            .iter()
            .map(|&x| x as usize)
            .collect();
        let steps = if fast { 10 } else { 20 };
        let res = chimbuko::exp::run_fig7(&scales, steps, 4, args.u64_opt("seed", 7));
        print!("{}", res.render());
        let shard_counts: Vec<usize> = args
            .u64_list("ps-shards", if fast { &[1, 2] } else { &[1, 2, 4, 8] })
            .iter()
            .map(|&x| x as usize)
            .collect();
        let sweep = chimbuko::exp::run_ps_shard_sweep(
            &shard_counts,
            if fast { 4 } else { 8 },
            if fast { 200 } else { 1_000 },
            if fast { 64 } else { 128 },
            args.u64_opt("seed", 7),
        );
        print!("{}", sweep.render());
        // NB: named --endpoint-counts, not --ps-endpoints: the latter is
        // a list of shard-server *addresses* on `run`/`ps-server`, while
        // this sweep takes endpoint *counts*.
        let endpoint_counts: Vec<usize> = args
            .u64_list("endpoint-counts", if fast { &[1, 2] } else { &[1, 2, 4, 8] })
            .iter()
            .map(|&x| x as usize)
            .collect();
        let eps = chimbuko::exp::run_ps_endpoint_sweep(
            &endpoint_counts,
            if fast { 4 } else { 8 },
            if fast { 100 } else { 500 },
            if fast { 64 } else { 128 },
            args.u64_opt("seed", 7),
        )?;
        print!("{}", eps.render());
        let reb = chimbuko::exp::run_ps_rebalance_sweep(
            args.usize_opt("rebalance-shards", 4),
            if fast { 2 } else { 4 },
            if fast { 400 } else { 2_000 },
            args.u64_opt("seed", 7),
        );
        print!("{}", reb.render());
        Ok(())
    };
    let run_fig8 = || -> anyhow::Result<()> {
        let scales: Vec<usize> = args
            .u64_list("scales", if fast { &[8, 32] } else { &[80, 160, 320, 640, 1280, 2560] })
            .iter()
            .map(|&x| x as usize)
            .collect();
        let res = chimbuko::exp::run_fig8(
            &scales,
            if fast { 4 } else { 8 },
            130,
            if fast { 1 } else { 3 },
            if fast { 500 } else { 2_000 },
        )?;
        print!("{}", res.render());
        Ok(())
    };
    let run_fig9 = || -> anyhow::Result<()> {
        let scales: Vec<usize> = args
            .u64_list("scales", if fast { &[8, 16] } else { &[80, 160, 320, 640, 1280, 2560] })
            .iter()
            .map(|&x| x as usize)
            .collect();
        let res = chimbuko::exp::run_fig9(&scales, if fast { 8 } else { 15 }, 130)?;
        print!("{}", res.render());
        let pdb = chimbuko::exp::run_provdb_bench(
            if fast { &[1, 2] } else { &[1, 2, 4] },
            if fast { 4 } else { 8 },
            if fast { 1_000 } else { 10_000 },
            if fast { 50 } else { 200 },
            args.usize_opt("provdb-max-per-rank", 1_000),
            args.u64_opt("seed", 7),
        )?;
        print!("{}", pdb.render());
        let codec = chimbuko::exp::run_codec_bench(
            4,
            if fast { 4 } else { 8 },
            if fast { 2_000 } else { 10_000 },
            if fast { 30 } else { 120 },
            args.u64_opt("seed", 7),
        )?;
        print!("{}", codec.render());
        Ok(())
    };
    let run_viz = || -> anyhow::Result<()> {
        let res = chimbuko::exp::run_figs3_6(
            if fast { 16 } else { 64 },
            if fast { 20 } else { 40 },
            args.u64_opt("seed", 4242),
        )?;
        print!("{}", res.render());
        Ok(())
    };
    let run_case = || -> anyhow::Result<()> {
        let res = chimbuko::exp::run_case_study(
            if fast { 8 } else { 16 },
            if fast { 50 } else { 100 },
            args.u64_opt("seed", 777),
        )?;
        print!("{}", res.render());
        Ok(())
    };
    let run_chaos = || -> anyhow::Result<()> {
        let bin = chimbuko::exp::find_chimbuko_bin()
            .ok_or_else(|| anyhow::anyhow!("chimbuko binary not found (set CHIMBUKO_BIN)"))?;
        let res = chimbuko::exp::run_chaos(
            &bin,
            args.usize_opt("shards", 2),
            args.usize_opt("ranks", if fast { 4 } else { 8 }),
            args.usize_opt("steps", if fast { 12 } else { 24 }),
            args.u64_opt("seed", 7),
        )?;
        print!("{}", res.render());
        Ok(())
    };
    match which {
        "fig7" => run_fig7()?,
        "fig8" | "table1" => run_fig8()?,
        "fig9" => run_fig9()?,
        "viz" | "figs3-6" => run_viz()?,
        "case" | "figs10-13" => run_case()?,
        "chaos" => run_chaos()?,
        "all" => {
            run_fig7()?;
            run_fig8()?;
            run_fig9()?;
            run_viz()?;
            run_case()?;
            // chaos spawns server children of this very binary, so it
            // runs in "all" too — current_exe() is the binary here.
            run_chaos()?;
        }
        other => anyhow::bail!("unknown experiment '{other}' (fig7|fig8|fig9|viz|case|chaos|all)"),
    }
    Ok(())
}
