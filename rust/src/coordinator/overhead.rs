//! Overhead accounting (Fig 8 + Table I).
//!
//! The paper measures NWChem wall time in three configurations and defines
//!
//! ```text
//! overhead(%) = (T_m − T_app) / T_app × 100
//! ```
//!
//! Our substitute "application" is the trace generator itself (real work:
//! event synthesis), so all three modes share identical workload bytes and
//! the deltas isolate exactly what the paper isolates — the cost of trace
//! capture (BP) and of streaming analysis (SST + AD + PS). Each scale is
//! measured over `repeats` runs and averaged, mirroring the paper's 15
//! repetitions (scaled down for CI).

use super::driver::{run, Mode, RunReport};
use super::workflow::Workflow;
use crate::config::Config;
use anyhow::Result;

/// One row of the Fig 8 / Table I sweep.
#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub ranks: usize,
    /// Mean wall seconds per mode.
    pub t_app: f64,
    pub t_tau: f64,
    pub t_chimbuko: f64,
    /// Table I columns.
    pub overhead_tau_pct: f64,
    pub overhead_chimbuko_pct: f64,
}

/// Measure one scale point.
///
/// Modes are *interleaved* per repeat (app, tau, chimbuko, app, tau, …)
/// so slow drift in machine load hits all three alike, and the median of
/// repeats is reported (robust to one noisy run — we have no dedicated
/// Summit nodes here).
pub fn measure_scale(cfg: &Config, repeats: usize) -> Result<OverheadRow> {
    let w = Workflow::nwchem(cfg);
    let mut samples: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for _ in 0..repeats.max(1) {
        for (i, mode) in [Mode::AppOnly, Mode::Tau, Mode::TauChimbuko].iter().enumerate() {
            let r: RunReport = run(cfg, &w, *mode)?;
            samples[i].push(r.wall_seconds);
        }
    }
    let median = |xs: &[f64]| crate::util::percentile(xs, 50.0);
    let t_app = median(&samples[0]);
    let t_tau = median(&samples[1]);
    let t_chimbuko = median(&samples[2]);
    Ok(OverheadRow {
        ranks: cfg.ranks,
        t_app,
        t_tau,
        t_chimbuko,
        overhead_tau_pct: overhead_pct(t_app, t_tau),
        overhead_chimbuko_pct: overhead_pct(t_app, t_chimbuko),
    })
}

/// The paper's Eq. (1).
pub fn overhead_pct(t_app: f64, t_m: f64) -> f64 {
    if t_app <= 0.0 {
        return 0.0;
    }
    (t_m - t_app) / t_app * 100.0
}

/// Sweep the Table I rank scales.
pub fn sweep(base: &Config, scales: &[usize], repeats: usize) -> Result<Vec<OverheadRow>> {
    let mut rows = Vec::with_capacity(scales.len());
    for &ranks in scales {
        let mut cfg = base.clone();
        cfg.ranks = ranks;
        rows.push(measure_scale(&cfg, repeats)?);
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_formula_matches_paper_eq1() {
        // Table I's 1280-rank row: T grows 8.54% with TAU.
        let t_app = 100.0;
        assert!((overhead_pct(t_app, 108.54) - 8.54).abs() < 1e-9);
        assert_eq!(overhead_pct(0.0, 5.0), 0.0);
    }

    #[test]
    fn measure_scale_produces_ordered_costs() {
        let cfg = Config {
            ranks: 4,
            steps: 6,
            calls_per_step: 100,
            out_dir: String::new(),
            ..Config::default()
        };
        let row = measure_scale(&cfg, 1).unwrap();
        assert!(row.t_app > 0.0);
        // Chimbuko adds analysis work on top of generation; with tiny
        // configs jitter can dominate, so only sanity-check signs exist.
        assert!(row.t_chimbuko > 0.0 && row.t_tau > 0.0);
    }
}
