//! Online pipeline driver: wires generators → SST streams → on-node AD →
//! parameter server → provenance/viz, on a bounded worker pool (simulated
//! ranks are virtual, workers are physical).
//!
//! Three run modes mirror the paper's Fig 8 measurement matrix:
//!
//! * [`Mode::AppOnly`] — the applications alone ("NWChem");
//! * [`Mode::Tau`] — applications + trace capture to BP files
//!   ("NWChem + TAU");
//! * [`Mode::TauChimbuko`] — applications + SST streaming + the full
//!   Chimbuko analysis ("NWChem + TAU + Chimbuko").

use super::workflow::Workflow;
use crate::ad::{DetectorConfig, HbosConfig, HbosDetector, OnNodeAd, RustDetector, StackErrors};
use crate::adios::{sst_channel, BpWriter, SstReader, SstWriter, StepStatus};
use crate::config::{AdAlgorithm, Config, DetectorBackend};
use crate::provdb::ProvClient;
use crate::provenance::{ProvDb, RecordFormat, RunMetadata};
use crate::ps::{self, PsClient, VizSnapshot};
use crate::runtime::{RuntimeService, XlaDetector};
use crate::stats::RunStats;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

/// What runs on top of the applications.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Applications only (baseline "NWChem").
    AppOnly,
    /// Applications + BP trace dump ("NWChem + TAU").
    Tau,
    /// Applications + streaming + full analysis ("NWChem + TAU + Chimbuko").
    TauChimbuko,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::AppOnly => "app",
            Mode::Tau => "app+tau",
            Mode::TauChimbuko => "app+tau+chimbuko",
        }
    }
}

/// Everything a run produces (inputs to every experiment table/figure).
#[derive(Clone, Debug)]
pub struct RunReport {
    pub mode: &'static str,
    pub ranks: usize,
    pub steps: usize,
    /// End-to-end wall time of the run.
    pub wall_seconds: f64,
    /// Total events generated (func + comm) across all ranks.
    pub total_events: u64,
    /// Completed executions analysed (Chimbuko mode only).
    pub total_execs: u64,
    pub total_anomalies: u64,
    /// Records kept for provenance (anomalies + context).
    pub total_kept: u64,
    /// Kept records the sampling probe shed before the sink
    /// (0 without a `[probe] sample` gate).
    pub prov_shed: u64,
    /// In-flight provenance records abandoned after a send-side failure
    /// survived its one resend (remote sink only; the chaos plane's
    /// bounded-loss ledger — always 0 in a healthy run).
    pub prov_inflight_lost: u64,
    /// Global-event records the trigger probe pushed into provDB
    /// (0 without a `[probe] trigger`).
    pub trigger_pushed: u64,
    /// Bytes the BP engine wrote/would write (Tau mode).
    pub bp_bytes: u64,
    /// Bytes of reduced JSON output (Chimbuko mode).
    pub reduced_bytes: u64,
    /// Sum of per-step AD processing time across ranks (seconds).
    pub ad_seconds: f64,
    /// Mean/σ of per-(rank,step) AD latency.
    pub ad_step_latency: RunStats,
    pub stack_errors: StackErrors,
    /// SST writer backpressure events.
    pub writer_waits: u64,
    /// Final viz snapshot (empty outside Chimbuko mode).
    pub snapshot: VizSnapshot,
    /// All snapshots published during the run (timeline history).
    pub snapshots: Vec<VizSnapshot>,
    /// Where provenance was written, if on disk.
    pub out_dir: Option<PathBuf>,
}

impl RunReport {
    /// Data-reduction factor (BP baseline ÷ reduced); needs both sides —
    /// experiments compute it across paired runs.
    pub fn reduction_factor(bp_bytes: u64, reduced_bytes: u64) -> f64 {
        if reduced_bytes == 0 {
            f64::INFINITY
        } else {
            bp_bytes as f64 / reduced_bytes as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::str(self.mode)),
            ("ranks", Json::num(self.ranks as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("wall_seconds", Json::num(self.wall_seconds)),
            ("total_events", Json::num(self.total_events as f64)),
            ("total_execs", Json::num(self.total_execs as f64)),
            ("total_anomalies", Json::num(self.total_anomalies as f64)),
            ("total_kept", Json::num(self.total_kept as f64)),
            ("prov_shed", Json::num(self.prov_shed as f64)),
            ("prov_inflight_lost", Json::num(self.prov_inflight_lost as f64)),
            ("trigger_pushed", Json::num(self.trigger_pushed as f64)),
            ("bp_bytes", Json::num(self.bp_bytes as f64)),
            ("reduced_bytes", Json::num(self.reduced_bytes as f64)),
            ("ad_seconds", Json::num(self.ad_seconds)),
            ("writer_waits", Json::num(self.writer_waits as f64)),
        ])
    }
}

/// Per-rank state owned by the generator side.
struct GenRank {
    tracer: crate::trace::RankTracer,
    writer: Option<SstWriter>,
}

/// Simulated application compute: spin for ~`us` microseconds of CPU.
///
/// The paper's application (NWChem) is compute-bound; a sleep would not
/// contend for cores with the analysis, so the overhead measurements of
/// Fig 8 / Table I require real work here. Calibrated once per process.
fn app_compute(us: u64) {
    use std::sync::OnceLock;
    static ITERS_PER_US: OnceLock<u64> = OnceLock::new();
    let per_us = *ITERS_PER_US.get_or_init(|| {
        let t = Instant::now();
        let mut acc = 0u64;
        let n = 4_000_000u64;
        for i in 0..n {
            acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
        }
        std::hint::black_box(acc);
        let elapsed = t.elapsed().as_secs_f64().max(1e-9);
        ((n as f64 / elapsed) / 1e6).max(1.0) as u64
    });
    let mut acc = 0u64;
    for i in 0..us.saturating_mul(per_us) {
        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
    }
    std::hint::black_box(acc);
}

/// Per-rank state owned by the analysis side.
struct AdRank {
    app: u32,
    rank: u32,
    reader: SstReader,
    ad: OnNodeAd,
}

/// Probe-gated down-sampling in front of a worker's provenance sink
/// (`[probe] sample = ...` in the config). Records matching the probe's
/// predicate pass through its `sample` clause; non-matching records are
/// written unconditionally — the gate only thins the population the
/// probe names, it never widens what is kept.
///
/// The predicate runs on the encoded record bytes (the probe VM reads
/// header fields at fixed offsets — [`crate::probe::vm`]), so the gate
/// costs one codec encode into a reused scratch buffer per record.
struct SampleGate {
    probe: crate::probe::Probe,
    /// Matching records seen so far — the deterministic sample stream.
    counter: u64,
    /// Matching records dropped by the sample clause.
    shed: u64,
    scratch: Vec<u8>,
}

impl SampleGate {
    /// `true` = write the record, `false` = shed it.
    fn admit(&mut self, rec: &crate::provenance::ProvRecord) -> bool {
        self.scratch.clear();
        crate::provenance::codec::encode(rec, &mut self.scratch);
        if !self.probe.matches(&self.scratch) {
            return true;
        }
        let keep = self.probe.sample_keep(self.counter);
        self.counter += 1;
        if !keep {
            self.shed += 1;
        }
        keep
    }
}

/// Where an AD worker's kept records go: the networked provenance
/// database service (when `provdb.addr` is configured) or a local
/// [`ProvDb`] — the fallback single-process layout.
enum SinkDest {
    Local(ProvDb),
    Remote(ProvClient),
}

/// An AD worker's provenance sink: a destination plus an optional
/// probe-gated [`SampleGate`].
///
/// The remote destination is the zero-Json ingest path: `append_step`
/// encodes each kept record straight into the client's reused binary
/// batch buffer (`provenance::codec`), which ships `provdb.batch`
/// records per wire round-trip — no JSONL text or `Json` tree exists
/// anywhere between the detector and the shard store. The local
/// destination keeps the JSONL layout (it *is* the offline/edge dump).
struct ProvSink {
    dest: SinkDest,
    gate: Option<SampleGate>,
}

impl ProvSink {
    fn for_worker(
        provdb_addr: &str,
        provdb_batch: usize,
        wire: RecordFormat,
        dir: &Option<PathBuf>,
        sample_probe: Option<crate::probe::Probe>,
    ) -> ProvSink {
        let dest = if !provdb_addr.is_empty() {
            SinkDest::Remote(
                ProvClient::connect_with(provdb_addr, provdb_batch, wire)
                    .expect("connecting to provdb service"),
            )
        } else {
            match dir {
                Some(d) => SinkDest::Local(ProvDb::create(d).expect("prov dir")),
                None => SinkDest::Local(ProvDb::in_memory()),
            }
        };
        let gate = sample_probe.map(|probe| SampleGate {
            probe,
            counter: 0,
            shed: 0,
            scratch: Vec::with_capacity(256),
        });
        ProvSink { dest, gate }
    }

    fn append_step(&mut self, kept: &[crate::ad::Labeled], reg: &crate::trace::FuncRegistry) {
        let Some(gate) = &mut self.gate else {
            // Ungated: the batch paths (no per-record probe eval).
            match &mut self.dest {
                SinkDest::Local(db) => db.append_step(kept, reg).expect("prov append"),
                // A dead service must not kill the AD worker mid-run: the
                // client already counted the abandoned batch in its
                // `inflight_lost` ledger and will reconnect on the next
                // batch, so degrade to a warning and keep analysing.
                SinkDest::Remote(c) => {
                    if let Err(e) = c.append_step(kept, reg) {
                        crate::log_warn!("driver", "provdb append failed (counted): {e:#}");
                    }
                }
            }
            return;
        };
        for l in kept {
            let rec = crate::provenance::ProvRecord::from_labeled(l, reg.name(l.rec.fid));
            if !gate.admit(&rec) {
                continue;
            }
            match &mut self.dest {
                SinkDest::Local(db) => db.append_record(rec).expect("prov append"),
                SinkDest::Remote(c) => {
                    if let Err(e) = c.append(&rec) {
                        crate::log_warn!("driver", "provdb append failed (counted): {e:#}");
                    }
                }
            }
        }
    }

    fn flush(&mut self) {
        match &mut self.dest {
            SinkDest::Local(db) => db.flush().expect("prov flush"),
            SinkDest::Remote(c) => {
                if let Err(e) = c.flush() {
                    crate::log_warn!("driver", "provdb flush failed (counted): {e:#}");
                }
            }
        }
    }

    /// Records the sample gate dropped (0 when ungated).
    fn shed(&self) -> u64 {
        self.gate.as_ref().map_or(0, |g| g.shed)
    }

    /// Records this worker's client abandoned mid-flight (remote only) —
    /// the per-worker slice of the chaos plane's bounded-loss ledger.
    fn inflight_lost(&self) -> u64 {
        match &self.dest {
            SinkDest::Local(_) => 0,
            SinkDest::Remote(c) => c.inflight_lost(),
        }
    }

    /// Locally written reduced bytes (remote writers report 0 — the
    /// service's log total is collected once, post-run; under the
    /// binary segment log that total is the *binary* byte count, i.e.
    /// the real on-disk reduced size).
    fn local_bytes_written(&self) -> u64 {
        match &self.dest {
            SinkDest::Local(db) => db.bytes_written(),
            SinkDest::Remote(_) => 0,
        }
    }
}

/// Run the workflow per `cfg` in the given mode.
pub fn run(cfg: &Config, workflow: &Workflow, mode: Mode) -> Result<RunReport> {
    cfg.validate()?;
    let unfiltered = !cfg.filtered;
    let mut root_rng = crate::util::rng::Rng::new(cfg.seed);

    // Optional XLA runtime (shared service thread).
    let runtime: Option<Arc<RuntimeService>> =
        if mode == Mode::TauChimbuko && cfg.backend == DetectorBackend::Xla {
            let svc = RuntimeService::spawn(std::path::Path::new(&cfg.artifacts_dir))?;
            anyhow::ensure!(
                workflow.max_funcs() <= svc.meta().funcs,
                "workflow has {} functions, artifact capacity is {}",
                workflow.max_funcs(),
                svc.meta().funcs
            );
            Some(Arc::new(svc))
        } else {
            None
        };

    // Probe surfaces: the per-worker sampling gate and the aggregator
    // trigger forwarder. Both compile here (validate() already proved
    // the sources compile) — before the PS spawns, because the trigger
    // channel is part of its options.
    let use_provdb = mode == Mode::TauChimbuko && !cfg.provdb_addr.is_empty();
    let sample_probe: Option<crate::probe::Probe> = if cfg.probe_sample.is_empty() {
        None
    } else {
        Some(crate::probe::Probe::compile(&cfg.probe_sample).context("compiling probe.sample")?)
    };
    // Trigger hits flow aggregator → this channel → a forwarder thread
    // that owns its own provDB connection and flushes per record, so a
    // matching global event lands in the service immediately — never
    // behind any worker's batch buffer or the next sync period.
    let (trigger_probes, trigger_tx, trigger_join) = if use_provdb
        && !cfg.probe_trigger.is_empty()
    {
        let probe = Arc::new(
            crate::probe::Probe::compile(&cfg.probe_trigger).context("compiling probe.trigger")?,
        );
        let (tx, rx) = channel::<crate::provenance::ProvRecord>();
        let addr = cfg.provdb_addr.clone();
        let join = std::thread::Builder::new()
            .name("chimbuko-probe-trigger".into())
            .spawn(move || {
                let mut client = match ProvClient::connect(&addr) {
                    Ok(c) => c,
                    Err(e) => {
                        crate::log_warn!("driver", "trigger forwarder connect failed: {e:#}");
                        while rx.recv().is_ok() {}
                        return 0u64;
                    }
                };
                let mut pushed = 0u64;
                while let Ok(rec) = rx.recv() {
                    match client.append(&rec).and_then(|()| client.flush()) {
                        Ok(()) => pushed += 1,
                        Err(e) => crate::log_warn!("driver", "trigger push failed: {e:#}"),
                    }
                }
                pushed
            })
            .context("spawning trigger forwarder")?;
        (vec![probe], Some(tx), Some(join))
    } else {
        (Vec::new(), None, None)
    };

    // Parameter server + viz collector (Chimbuko mode only). Publish
    // cadence is one snapshot per step-round (plus the optional
    // wall-clock cadence); the per-step report quorum is the number of
    // reporting ranks — independent knobs (conflating them completes
    // global-event steps early/late). With `ps.endpoints` configured the
    // stat shards are remote `ps-shard-server` processes and only the
    // aggregator/front-end runs here.
    let (viz_tx, viz_rx) = channel::<VizSnapshot>();
    let (ps_client, ps_handle) = if mode == Mode::TauChimbuko {
        let (c, h) = ps::spawn_with(ps::PsOpts {
            shards: cfg.ps_shards,
            endpoints: cfg.ps_endpoints.clone(),
            conn_pool: cfg.ps_conn_pool,
            viz_tx: Some(viz_tx),
            publish_every: cfg.ranks.max(1),
            publish_interval_ms: cfg.publish_interval_ms,
            reports_per_step: cfg.ranks,
            rebalance_interval_ms: cfg.ps_rebalance_interval_ms,
            rebalance_max_ratio: cfg.ps_rebalance_max_ratio,
            rebalance_min_merges: cfg.ps_rebalance_min_merges,
            agg_fanout: cfg.ps_agg_fanout,
            agg_endpoints: cfg.ps_agg_endpoints.clone(),
            trigger_probes,
            trigger_tx,
        })
        .context("spawning parameter server")?;
        (Some(c), Some(h))
    } else {
        drop(viz_tx);
        (None, None)
    };
    let viz_collector = std::thread::spawn(move || {
        let mut all = Vec::new();
        while let Ok(s) = viz_rx.recv() {
            all.push(s);
        }
        all
    });

    // Provenance sink (one per AD worker: same directory locally, or one
    // batching connection each to the provDB service). A configured
    // `provdb.addr` takes precedence over `out_dir` — records then live
    // in the service (which has its own data directory).
    let out_dir: Option<PathBuf> =
        if mode == Mode::TauChimbuko && !cfg.out_dir.is_empty() && !use_provdb {
            let d = PathBuf::from(&cfg.out_dir);
            std::fs::create_dir_all(&d).ok();
            Some(d)
        } else {
            None
        };

    let pool = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(cfg.ranks)
        .max(1);

    // Partition ranks into `pool` slices; build per-rank state.
    let mut gen_slices: Vec<Vec<GenRank>> = (0..pool).map(|_| Vec::new()).collect();
    let mut ad_slices: Vec<Vec<AdRank>> = (0..pool).map(|_| Vec::new()).collect();
    for a in &workflow.assignments {
        let slice = (a.rank as usize) % pool;
        let rng = root_rng.fork(a.rank as u64);
        let tracer = crate::trace::RankTracer::new(
            workflow.grammars[a.app as usize].clone(),
            a.app,
            a.app_rank,
            workflow.app_world(a.app),
            unfiltered,
            rng,
        );
        if mode == Mode::TauChimbuko {
            let (w, r) = sst_channel(cfg.sst_queue_depth);
            gen_slices[slice].push(GenRank { tracer, writer: Some(w) });
            let engine: Box<dyn crate::ad::DetectEngine> = match (&runtime, cfg.algorithm) {
                (Some(svc), _) => Box::new(XlaDetector::new(
                    svc.handle(),
                    cfg.alpha,
                    DetectorConfig::default().min_samples,
                )),
                (None, AdAlgorithm::Threshold) => Box::new(RustDetector::new(DetectorConfig {
                    alpha: cfg.alpha,
                    min_samples: DetectorConfig::default().min_samples,
                })),
                (None, AdAlgorithm::Hbos) => {
                    Box::new(HbosDetector::new(HbosConfig::default()))
                }
            };
            ad_slices[slice].push(AdRank {
                app: a.app,
                rank: a.rank,
                reader: r,
                ad: OnNodeAd::new(a.app, a.rank, cfg.k_neighbors, engine),
            });
        } else {
            gen_slices[slice].push(GenRank { tracer, writer: None });
        }
    }

    // Run metadata (written once — to the provDB service when
    // configured, to the local store otherwise). The service may be
    // long-lived and hold prior runs' records (restart recovery), so
    // snapshot its log size here: this run's reduced_bytes is the
    // delta, matching the local path (which also excludes metadata).
    let mut provdb_baseline_bytes = 0u64;
    if use_provdb {
        let meta = RunMetadata::new(
            &format!("run-seed{}-r{}", cfg.seed, cfg.ranks),
            cfg.to_json(),
            &workflow.registries,
        );
        let mut client = ProvClient::connect(&cfg.provdb_addr)
            .context("connecting to provdb service for metadata")?;
        client.set_metadata(&meta.to_json())?;
        provdb_baseline_bytes = client.stats()?.log_bytes;
    } else if let Some(dir) = &out_dir {
        let mut db = ProvDb::create(dir)?;
        db.write_metadata(&RunMetadata::new(
            &format!("run-seed{}-r{}", cfg.seed, cfg.ranks),
            cfg.to_json(),
            &workflow.registries,
        ))?;
        db.flush()?;
    }

    let steps = cfg.steps;
    let t0 = Instant::now();

    // ---- Generator workers ------------------------------------------------
    let engine_is_bp = mode == Mode::Tau;
    // Strong scaling: fixed total app work split across rank-steps.
    let app_us_per_rank_step = if cfg.app_work_ms_total == 0 {
        0
    } else {
        (cfg.app_work_ms_total * 1000) / (cfg.ranks as u64 * steps as u64).max(1)
    };
    let mut gen_joins = Vec::new();
    for (wi, mut slice) in gen_slices.into_iter().enumerate() {
        let join = std::thread::Builder::new()
            .name(format!("chimbuko-gen-{wi}"))
            .spawn(move || {
                let mut bp = BpWriter::counting();
                let mut events = 0u64;
                let mut waits = 0u64;
                for _step in 0..steps {
                    for g in slice.iter_mut() {
                        if app_us_per_rank_step > 0 {
                            app_compute(app_us_per_rank_step);
                        }
                        let frame = g.tracer.step();
                        events += frame.events.len() as u64;
                        if engine_is_bp {
                            bp.put_step(&frame).expect("bp write");
                        }
                        if let Some(w) = &g.writer {
                            w.put_step(frame);
                        }
                    }
                }
                for g in &slice {
                    if let Some(w) = &g.writer {
                        waits += w.writer_waits();
                        w.close();
                    }
                }
                (events, bp.bytes_written(), waits)
            })
            .context("spawning generator worker")?;
        gen_joins.push(join);
    }

    // ---- AD workers (Chimbuko mode) ---------------------------------------
    struct AdWorkerOut {
        execs: u64,
        anomalies: u64,
        kept: u64,
        shed: u64,
        prov_inflight_lost: u64,
        ad_seconds: f64,
        latency: RunStats,
        reduced_bytes: u64,
        errors: StackErrors,
    }
    let mut ad_joins = Vec::new();
    if mode == Mode::TauChimbuko {
        for (wi, mut slice) in ad_slices.into_iter().enumerate() {
            let client: PsClient = ps_client.clone().unwrap();
            let dir = out_dir.clone();
            let regs = workflow.registries.clone();
            let ps_period = cfg.ps_period_steps;
            let provdb_addr = cfg.provdb_addr.clone();
            let provdb_batch = cfg.provdb_batch;
            let provdb_wire = cfg.provdb_log_format;
            let sample = sample_probe.clone();
            let join = std::thread::Builder::new()
                .name(format!("chimbuko-ad-{wi}"))
                .spawn(move || {
                    let mut db =
                        ProvSink::for_worker(&provdb_addr, provdb_batch, provdb_wire, &dir, sample);
                    let mut out = AdWorkerOut {
                        execs: 0,
                        anomalies: 0,
                        kept: 0,
                        shed: 0,
                        prov_inflight_lost: 0,
                        ad_seconds: 0.0,
                        latency: RunStats::new(),
                        reduced_bytes: 0,
                        errors: StackErrors::default(),
                    };
                    for step in 0..steps as u64 {
                        for r in slice.iter_mut() {
                            let frame = match r.reader.begin_step() {
                                StepStatus::Ok(f) => f,
                                StepStatus::EndOfStream => continue,
                                StepStatus::NotReady => unreachable!(),
                            };
                            let span = frame.span().unwrap_or((0, 0));
                            let res = r.ad.process_step(&frame);
                            out.execs += res.n_executions;
                            out.anomalies += res.n_anomalies;
                            out.kept += res.kept.len() as u64;
                            out.ad_seconds += res.proc_seconds;
                            out.latency.push(res.proc_seconds);
                            if !res.kept.is_empty() {
                                db.append_step(&res.kept, &regs[r.app as usize]);
                            }
                            client.report(ps::step_stat_of(&res, span));
                            if step % ps_period as u64 == ps_period as u64 - 1 {
                                let delta = r.ad.take_pending();
                                let (global, events) = client.sync(r.app, r.rank, &delta);
                                r.ad.adopt_global(&global);
                                if !events.is_empty() {
                                    // §V: globally detected event — dump
                                    // this rank's context window too.
                                    let dump = r.ad.dump_window();
                                    out.kept += dump.len() as u64;
                                    if !dump.is_empty() {
                                        db.append_step(&dump, &regs[r.app as usize]);
                                    }
                                }
                            }
                        }
                    }
                    // Drain any remaining steps (generator may be ahead on
                    // ranks this worker saw EndOfStream for early).
                    for r in slice.iter_mut() {
                        while let StepStatus::Ok(frame) = r.reader.begin_step() {
                            let span = frame.span().unwrap_or((0, 0));
                            let res = r.ad.process_step(&frame);
                            out.execs += res.n_executions;
                            out.anomalies += res.n_anomalies;
                            out.kept += res.kept.len() as u64;
                            out.ad_seconds += res.proc_seconds;
                            if !res.kept.is_empty() {
                                db.append_step(&res.kept, &regs[r.app as usize]);
                            }
                            client.report(ps::step_stat_of(&res, span));
                        }
                        out.errors.unmatched_exit += r.ad.stack_errors().unmatched_exit;
                        out.errors.time_regression += r.ad.stack_errors().time_regression;
                        out.errors.orphan_comm += r.ad.stack_errors().orphan_comm;
                    }
                    db.flush();
                    out.shed = db.shed();
                    out.prov_inflight_lost = db.inflight_lost();
                    out.reduced_bytes = db.local_bytes_written();
                    out
                })
                .context("spawning AD worker")?;
            ad_joins.push(join);
        }
    }

    // ---- Join -------------------------------------------------------------
    let mut total_events = 0u64;
    let mut bp_bytes = 0u64;
    let mut writer_waits = 0u64;
    for j in gen_joins {
        let (ev, bp, waits) = j.join().expect("generator worker panicked");
        total_events += ev;
        bp_bytes += bp;
        writer_waits += waits;
    }
    let mut execs = 0u64;
    let mut anomalies = 0u64;
    let mut kept = 0u64;
    let mut shed = 0u64;
    let mut prov_inflight_lost = 0u64;
    let mut ad_seconds = 0.0f64;
    let mut latency = RunStats::new();
    let mut reduced_bytes = 0u64;
    let mut errors = StackErrors::default();
    for j in ad_joins {
        let o = j.join().expect("AD worker panicked");
        execs += o.execs;
        anomalies += o.anomalies;
        kept += o.kept;
        shed += o.shed;
        prov_inflight_lost += o.prov_inflight_lost;
        ad_seconds += o.ad_seconds;
        latency.merge(&o.latency);
        reduced_bytes += o.reduced_bytes;
        errors.unmatched_exit += o.errors.unmatched_exit;
        errors.time_regression += o.errors.time_regression;
        errors.orphan_comm += o.errors.orphan_comm;
    }

    // Remote provenance: the per-worker sinks reported 0; collect the
    // service's log growth since the pre-run baseline (flush first — a
    // barrier across every shard — so all worker batches are accounted).
    if use_provdb {
        let mut client = ProvClient::connect(&cfg.provdb_addr)
            .context("connecting to provdb service for stats")?;
        client.flush()?;
        reduced_bytes = client.stats()?.log_bytes.saturating_sub(provdb_baseline_bytes);
    }

    // Shut the PS constellation down and collect snapshots.
    let snapshot = match (ps_client, ps_handle) {
        (Some(c), Some(h)) => {
            c.shutdown();
            let fin = h.join();
            drop(c);
            fin.snapshot
        }
        _ => VizSnapshot::default(),
    };
    // The aggregator owned the trigger channel's sender; with the PS
    // down the forwarder has drained its queue and exits.
    let trigger_pushed = trigger_join
        .map(|j| j.join().expect("trigger forwarder panicked"))
        .unwrap_or(0);
    let snapshots = viz_collector.join().expect("viz collector panicked");

    let wall = t0.elapsed().as_secs_f64();
    Ok(RunReport {
        mode: mode.name(),
        ranks: cfg.ranks,
        steps,
        wall_seconds: wall,
        total_events,
        total_execs: execs,
        total_anomalies: anomalies,
        total_kept: kept,
        prov_shed: shed,
        prov_inflight_lost,
        trigger_pushed,
        bp_bytes,
        reduced_bytes,
        ad_seconds,
        ad_step_latency: latency,
        stack_errors: errors,
        writer_waits,
        snapshot,
        snapshots,
        out_dir,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> Config {
        Config {
            ranks: 8,
            apps: 2,
            steps: 12,
            calls_per_step: 130,
            out_dir: String::new(), // in-memory provenance
            viz_enabled: true,
            ..Config::default()
        }
    }

    #[test]
    fn app_only_generates_events() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::AppOnly).unwrap();
        assert!(r.total_events > 1000);
        assert_eq!(r.total_execs, 0);
        assert_eq!(r.bp_bytes, 0);
        assert_eq!(r.reduced_bytes, 0);
    }

    #[test]
    fn tau_mode_counts_bp_bytes() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::Tau).unwrap();
        assert!(r.bp_bytes > 10_000);
        // ~14–26 B/event.
        let per_event = r.bp_bytes as f64 / r.total_events as f64;
        assert!(per_event > 10.0 && per_event < 30.0);
    }

    #[test]
    fn chimbuko_mode_full_pipeline() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        assert!(r.total_execs > 1000, "execs {}", r.total_execs);
        assert!(r.total_anomalies > 0, "no anomalies detected");
        assert!(r.total_kept >= r.total_anomalies);
        assert!(r.reduced_bytes > 0);
        assert_eq!(r.stack_errors, StackErrors::default());
        // The dashboard saw every rank.
        assert_eq!(r.snapshot.ranks.len(), cfg.ranks);
        assert_eq!(r.snapshot.total_executions, r.total_execs);
        assert_eq!(r.snapshot.total_anomalies, r.total_anomalies);
        assert!(!r.snapshots.is_empty());
    }

    #[test]
    fn sampling_probe_gates_the_prov_sink() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let base = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        assert!(base.reduced_bytes > 0);
        assert_eq!(base.prov_shed, 0);

        // A match-everything probe keeping 0/1: the sink writes nothing.
        let mut cfg_none = small_cfg();
        cfg_none.probe_sample = "fn:*.*:exit / 0 == 0 / sample 0/1".into();
        let none = run(&cfg_none, &w, Mode::TauChimbuko).unwrap();
        assert_eq!(none.reduced_bytes, 0);
        assert_eq!(none.prov_shed, none.total_kept);
        assert_eq!(none.total_kept, base.total_kept, "gate must not change detection");

        // A match-nothing probe: the gate passes every record through.
        let mut cfg_all = small_cfg();
        cfg_all.probe_sample = "fn:*.*:exit / score < 0.0 && score > 1.0 / sample 0/1".into();
        let all = run(&cfg_all, &w, Mode::TauChimbuko).unwrap();
        assert_eq!(all.prov_shed, 0);
        assert_eq!(all.reduced_bytes, base.reduced_bytes);
    }

    #[test]
    fn chimbuko_reduction_vs_tau_baseline() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let tau = run(&cfg, &w, Mode::Tau).unwrap();
        let chi = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        let factor = RunReport::reduction_factor(tau.bp_bytes, chi.reduced_bytes);
        assert!(factor > 2.0, "reduction factor {factor}");
        // Same workload generated in both modes (same seed).
        assert_eq!(tau.total_events, chi.total_events);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg();
        let w = Workflow::nwchem(&cfg);
        let a = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        let b = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        assert_eq!(a.total_events, b.total_events);
        assert_eq!(a.total_execs, b.total_execs);
        assert_eq!(a.total_anomalies, b.total_anomalies);
        assert_eq!(a.total_kept, b.total_kept);
    }

    #[test]
    fn shard_count_does_not_change_results() {
        // The quickstart-shaped workflow must produce the same report
        // whether the PS runs as one shard (single-server layout) or many.
        let mut totals = Vec::new();
        for shards in [1usize, 2, 4] {
            let mut cfg = small_cfg();
            cfg.ps_shards = shards;
            let w = Workflow::nwchem(&cfg);
            let r = run(&cfg, &w, Mode::TauChimbuko).unwrap();
            assert_eq!(r.snapshot.ranks.len(), cfg.ranks, "shards={shards}");
            // Note: global-event counts are excluded — detection depends
            // on step-completion order under concurrent AD workers, which
            // is scheduling- (not shard-) dependent.
            totals.push((
                r.total_events,
                r.total_execs,
                r.total_anomalies,
                r.total_kept,
                r.snapshot.total_anomalies,
            ));
        }
        assert_eq!(totals[0], totals[1], "1 vs 2 shards diverged");
        assert_eq!(totals[1], totals[2], "2 vs 4 shards diverged");
    }

    #[test]
    fn disk_provenance_roundtrip() {
        let dir = std::env::temp_dir().join(format!("chimbuko-run-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let mut cfg = small_cfg();
        cfg.out_dir = dir.to_str().unwrap().to_string();
        let w = Workflow::nwchem(&cfg);
        let r = run(&cfg, &w, Mode::TauChimbuko).unwrap();
        assert!(dir.join("metadata.json").exists());
        let db = ProvDb::load(&dir).unwrap();
        assert_eq!(db.len() as u64, r.total_kept);
        assert_eq!(db.anomaly_count(), r.total_anomalies);
        let meta = ProvDb::load_metadata(&dir).unwrap();
        assert!(meta.get("config").is_some());
        std::fs::remove_dir_all(&dir).ok();
    }
}
