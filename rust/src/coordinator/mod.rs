//! The Chimbuko coordinator (paper §II): workflow topology, the online
//! pipeline driver and the overhead-measurement harness.

pub mod driver;
pub mod offline;
pub mod overhead;
pub mod supervise;
pub mod workflow;

pub use driver::{run, Mode, RunReport};
pub use offline::{analyze_bp, OfflineReport};
pub use overhead::{measure_scale, overhead_pct, sweep, OverheadRow};
pub use supervise::{pick_addr, ChildSpec, Supervisor};
pub use workflow::{RankAssignment, Workflow};
