//! Offline mode (paper §II-B: "all Chimbuko components can be run both in
//! on- and off-line modes, allowing users to reinvestigate and compare
//! performance data across a number of runs").
//!
//! Re-analyzes a stored BP trace file post-hoc: frames are streamed off
//! disk in file order through the same on-node AD modules, statistics,
//! provenance and summary machinery as the online pipeline — so an
//! offline pass over a dumped trace produces byte-compatible provenance.

use crate::ad::{DetectorConfig, HbosConfig, HbosDetector, OnNodeAd, RustDetector};
use crate::config::{AdAlgorithm, Config};
use crate::provenance::ProvDb;
use crate::stats::RunStats;
use crate::trace::binfmt;
use crate::trace::nwchem::workflow_registries;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::fs::File;
use std::io::BufReader;
use std::path::Path;

/// Result of an offline analysis pass.
#[derive(Clone, Debug)]
pub struct OfflineReport {
    pub frames: u64,
    pub events: u64,
    pub execs: u64,
    pub anomalies: u64,
    pub kept: u64,
    pub reduced_bytes: u64,
    /// Per-(app, rank) anomaly totals.
    pub per_rank: Vec<((u32, u32), u64)>,
    /// Wall time of the analysis itself.
    pub wall_seconds: f64,
    /// Per-function anomaly runtime stats (top offenders view).
    pub per_func_anoms: Vec<(String, u64, RunStats)>,
}

impl OfflineReport {
    pub fn render(&self) -> String {
        let mut out = format!(
            "== Offline analysis ==\n\
             frames {}  events {}  executions {}\n\
             anomalies {} ({:.3}%)  kept {}  reduced {}\n\
             analysis wall time {:.3}s ({:.0} events/s)\n",
            self.frames,
            self.events,
            self.execs,
            self.anomalies,
            100.0 * self.anomalies as f64 / self.execs.max(1) as f64,
            self.kept,
            crate::util::fmt_bytes(self.reduced_bytes),
            self.wall_seconds,
            self.events as f64 / self.wall_seconds.max(1e-9),
        );
        out.push_str("top anomalous functions:\n");
        for (func, n, st) in self.per_func_anoms.iter().take(8) {
            out.push_str(&format!(
                "   {:<16} {:>6} anomalies, mean {:.0}µs max {:.0}µs\n",
                func,
                n,
                st.mean(),
                st.max()
            ));
        }
        out
    }
}

/// Analyze a BP trace file with the configured detector; optionally write
/// provenance to `cfg.out_dir`.
pub fn analyze_bp(path: &Path, cfg: &Config) -> Result<OfflineReport> {
    let t0 = std::time::Instant::now();
    let file = File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut reader = BufReader::new(file);

    let registries = workflow_registries();
    let mut db = if cfg.out_dir.is_empty() {
        ProvDb::in_memory()
    } else {
        ProvDb::create(Path::new(&cfg.out_dir))?
    };

    let mut modules: HashMap<(u32, u32), OnNodeAd> = HashMap::new();
    let mut frames = 0u64;
    let mut events = 0u64;
    let mut execs = 0u64;
    let mut anomalies = 0u64;
    let mut kept = 0u64;
    let mut per_func: HashMap<String, (u64, RunStats)> = HashMap::new();

    while let Some(frame) = binfmt::read_frame(&mut reader)? {
        frames += 1;
        events += frame.events.len() as u64;
        let key = (frame.app, frame.rank);
        let ad = modules.entry(key).or_insert_with(|| {
            let engine: Box<dyn crate::ad::DetectEngine> = match cfg.algorithm {
                AdAlgorithm::Threshold => Box::new(RustDetector::new(DetectorConfig {
                    alpha: cfg.alpha,
                    min_samples: DetectorConfig::default().min_samples,
                })),
                AdAlgorithm::Hbos => Box::new(HbosDetector::new(HbosConfig::default())),
            };
            OnNodeAd::new(frame.app, frame.rank, cfg.k_neighbors, engine)
        });
        let res = ad.process_step(&frame);
        execs += res.n_executions;
        anomalies += res.n_anomalies;
        kept += res.kept.len() as u64;
        if !res.kept.is_empty() {
            let reg = &registries[frame.app.min(registries.len() as u32 - 1) as usize];
            for l in &res.kept {
                if l.label.is_anomaly() {
                    let e = per_func
                        .entry(reg.name(l.rec.fid).to_string())
                        .or_insert_with(|| (0, RunStats::new()));
                    e.0 += 1;
                    e.1.push(l.rec.inclusive_us() as f64);
                }
            }
            db.append_step(&res.kept, reg)?;
        }
    }
    db.flush()?;

    let mut per_rank: Vec<((u32, u32), u64)> = modules
        .iter()
        .map(|(k, m)| (*k, m.totals().1))
        .collect();
    per_rank.sort();
    let mut per_func_anoms: Vec<(String, u64, RunStats)> = per_func
        .into_iter()
        .map(|(f, (n, st))| (f, n, st))
        .collect();
    per_func_anoms.sort_by(|a, b| b.1.cmp(&a.1));

    Ok(OfflineReport {
        frames,
        events,
        execs,
        anomalies,
        kept,
        reduced_bytes: db.bytes_written(),
        per_rank,
        wall_seconds: t0.elapsed().as_secs_f64(),
        per_func_anoms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adios::BpWriter;
    use crate::coordinator::Workflow;
    use crate::trace::RankTracer;
    use crate::util::rng::Rng;

    fn write_trace(path: &Path, ranks: usize, steps: usize, seed: u64) {
        let cfg = Config { ranks, apps: 1, steps, calls_per_step: 130, ..Config::default() };
        let workflow = Workflow::nwchem(&cfg);
        let mut writer = BpWriter::create(path).unwrap();
        let mut rng = Rng::new(seed);
        for a in &workflow.assignments {
            let mut tracer = RankTracer::new(
                workflow.grammars[a.app as usize].clone(),
                a.app,
                a.app_rank,
                workflow.app_world(a.app),
                false,
                rng.fork(a.rank as u64),
            );
            for _ in 0..steps {
                writer.put_step(&tracer.step()).unwrap();
            }
        }
        writer.flush().unwrap();
    }

    #[test]
    fn offline_analysis_of_dumped_trace() {
        let dir = std::env::temp_dir().join(format!("chimbuko-off-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("trace.bp");
        write_trace(&bp, 6, 30, 42);

        let cfg = Config { out_dir: String::new(), ..Config::default() };
        let rep = analyze_bp(&bp, &cfg).unwrap();
        assert_eq!(rep.frames, 6 * 30);
        assert!(rep.execs > 2000);
        assert!(rep.anomalies > 0, "stored trace must contain anomalies");
        assert!(rep.kept >= rep.anomalies);
        assert!(!rep.per_func_anoms.is_empty());
        let text = rep.render();
        assert!(text.contains("Offline analysis"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn offline_deterministic_and_writes_provenance() {
        let dir = std::env::temp_dir().join(format!("chimbuko-off2-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("trace.bp");
        write_trace(&bp, 4, 20, 7);

        let out = dir.join("prov");
        let cfg = Config {
            out_dir: out.to_str().unwrap().to_string(),
            ..Config::default()
        };
        let a = analyze_bp(&bp, &cfg).unwrap();
        let loaded = ProvDb::load(&out).unwrap();
        assert_eq!(loaded.len() as u64, a.kept);
        assert_eq!(loaded.anomaly_count(), a.anomalies);

        // Second pass over the same file gives identical results.
        let cfg2 = Config { out_dir: String::new(), ..Config::default() };
        let b = analyze_bp(&bp, &cfg2).unwrap();
        assert_eq!(a.anomalies, b.anomalies);
        assert_eq!(a.kept, b.kept);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn offline_with_hbos_algorithm() {
        let dir = std::env::temp_dir().join(format!("chimbuko-off3-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let bp = dir.join("trace.bp");
        write_trace(&bp, 4, 40, 9);
        let cfg = Config {
            algorithm: AdAlgorithm::Hbos,
            out_dir: String::new(),
            ..Config::default()
        };
        let rep = analyze_bp(&bp, &cfg).unwrap();
        assert!(rep.execs > 1000);
        std::fs::remove_dir_all(&dir).ok();
    }
}
