//! Chaos-plane supervisor: spawns the workflow's server children
//! (`ps-shard-server`, `provdb-server`, `agg-node`), watches them, and
//! restarts a dead one *into the same endpoint slot* so every client
//! heals through its existing `Reconnector`/`Rerouted` path instead of
//! being reconfigured (`rust/docs/chaos.md`).
//!
//! The supervisor is also the executor of a [`FaultPlan`]'s kill
//! schedule: the chaos harness calls [`Supervisor::kill`] /
//! [`Supervisor::respawn`] at the sync steps the plan names, and the
//! plan itself rides to every child through the `CHIMBUKO_CHAOS`
//! environment variable (each server calls
//! [`fault::init_from_env`](crate::util::fault::init_from_env) at
//! startup), so one seed reproduces the same schedule in every process.
//!
//! Restart-with-state: a PS stat shard's keyed table can be
//! checkpointed through [`Supervisor::ps_extract`] (non-destructive
//! `KIND_EXTRACT` dump) and re-seeded into the respawned process with
//! [`Supervisor::ps_install`]; a provDB shard recovers from its own
//! `.provseg` log (footer-first streaming recovery) and needs no seed.

use crate::util::fault::{FaultPlan, KillTarget};
use crate::util::log::trace_step;
use anyhow::{Context, Result};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::process::{Child as ChildProc, Command, Stdio};
use std::time::{Duration, Instant};

/// How long [`Supervisor::await_ready`] polls a child's endpoint before
/// giving up (cold target directories + debug builds are slow).
const READY_TIMEOUT: Duration = Duration::from_secs(30);
const READY_POLL: Duration = Duration::from_millis(20);

/// Pick a free loopback port by binding `127.0.0.1:0` and immediately
/// dropping the listener. The port is chosen *before* the child spawns
/// so its endpoint address is stable across restarts — the whole point
/// of slot-preserving supervision. (The tiny window in which another
/// process could grab the port is acceptable for tests/harnesses; a
/// production deployment assigns ports explicitly.)
pub fn pick_addr() -> Result<String> {
    let l = TcpListener::bind("127.0.0.1:0").context("picking a free port")?;
    Ok(l.local_addr().context("reading picked port")?.to_string())
}

/// Everything needed to (re)spawn one child into its slot: the argv is
/// replayed verbatim on every respawn, so the child always comes back at
/// the same address with the same identity flags.
#[derive(Clone, Debug)]
pub struct ChildSpec {
    /// Which server class this is (also the kill-spec namespace).
    pub target: KillTarget,
    /// Slot index within the class (kill specs say `ps:0@6`).
    pub index: usize,
    /// The endpoint the child serves (stable across restarts).
    pub addr: String,
    /// Full argv after the binary name, `--addr` included.
    pub args: Vec<String>,
}

impl ChildSpec {
    /// A `ps-shard-server` slot.
    pub fn ps_shard(index: usize, shards: usize, addr: &str) -> ChildSpec {
        ChildSpec {
            target: KillTarget::PsShard,
            index,
            addr: addr.to_string(),
            args: vec![
                "ps-shard-server".into(),
                "--addr".into(),
                addr.to_string(),
                "--shard-id".into(),
                index.to_string(),
                "--shards".into(),
                shards.to_string(),
            ],
        }
    }

    /// A `provdb-server` slot. `dir` is the shard's durable log
    /// directory — restart recovery replays it, so it must survive the
    /// process (pass the same directory on every respawn).
    pub fn provdb(index: usize, shards: usize, addr: &str, dir: &std::path::Path) -> ChildSpec {
        ChildSpec {
            target: KillTarget::ProvDb,
            index,
            addr: addr.to_string(),
            args: vec![
                "provdb-server".into(),
                "--addr".into(),
                addr.to_string(),
                "--shards".into(),
                shards.to_string(),
                "--dir".into(),
                dir.display().to_string(),
            ],
        }
    }

    /// An `agg-node` leaf slot covering ranks `[rank_lo, rank_hi)`.
    pub fn agg_node(index: usize, addr: &str, rank_lo: u32, rank_hi: u32) -> ChildSpec {
        ChildSpec {
            target: KillTarget::AggNode,
            index,
            addr: addr.to_string(),
            args: vec![
                "agg-node".into(),
                "--addr".into(),
                addr.to_string(),
                "--node".into(),
                (index + 1).to_string(),
                "--rank-lo".into(),
                rank_lo.to_string(),
                "--rank-hi".into(),
                rank_hi.to_string(),
            ],
        }
    }
}

/// One supervised slot: its spec plus the live process (if any).
struct Slot {
    spec: ChildSpec,
    proc: Option<ChildProc>,
    /// Restarts this slot has been through (respawns + reaps).
    restarts: u64,
}

/// Spawns and supervises server children of one `chimbuko` binary.
///
/// Dropping the supervisor kills every remaining child — a panicking
/// harness must not leak server processes.
pub struct Supervisor {
    bin: PathBuf,
    /// `CHIMBUKO_CHAOS` spec handed to every child (empty = no chaos).
    chaos_spec: String,
    slots: Vec<Slot>,
}

impl Supervisor {
    /// Supervise children of the `chimbuko` binary at `bin`, with no
    /// fault plan in their environment.
    pub fn new(bin: PathBuf) -> Supervisor {
        Supervisor { bin, chaos_spec: String::new(), slots: Vec::new() }
    }

    /// Hand `plan` to every subsequently spawned child via the
    /// `CHIMBUKO_CHAOS` environment variable (the deterministic-replay
    /// hand-off: same seed, same schedule, every process).
    pub fn with_plan(mut self, plan: &FaultPlan) -> Supervisor {
        self.chaos_spec = plan.spec();
        self
    }

    /// Spawn `spec` and register its slot. Does *not* wait for
    /// readiness — call [`await_ready`](Self::await_ready) after
    /// spawning a batch so the children boot in parallel.
    pub fn spawn(&mut self, spec: ChildSpec) -> Result<()> {
        let proc = self.launch(&spec)?;
        trace_step("supervise", 0, &actor_of(&spec), "spawned", &spec.addr);
        self.slots.push(Slot { spec, proc: Some(proc), restarts: 0 });
        Ok(())
    }

    fn launch(&self, spec: &ChildSpec) -> Result<ChildProc> {
        let mut cmd = Command::new(&self.bin);
        cmd.args(&spec.args).stdin(Stdio::null()).stdout(Stdio::null());
        if !self.chaos_spec.is_empty() {
            cmd.env("CHIMBUKO_CHAOS", &self.chaos_spec);
        } else {
            // Never let a plan leak from the harness's own environment
            // into an unfaulted child — the control run must stay clean.
            cmd.env_remove("CHIMBUKO_CHAOS");
        }
        cmd.spawn().with_context(|| {
            format!("spawning {} {} via {}", actor_of(spec), spec.addr, self.bin.display())
        })
    }

    /// Block until every supervised endpoint accepts a TCP connection
    /// (the readiness probe — banner scraping would race buffering).
    pub fn await_ready(&self) -> Result<()> {
        for s in &self.slots {
            await_endpoint(&s.spec.addr)
                .with_context(|| format!("{} never became ready", actor_of(&s.spec)))?;
        }
        Ok(())
    }

    /// Kill the `(target, index)` child (SIGKILL — a crash, not a
    /// shutdown). The slot stays registered; [`respawn`](Self::respawn)
    /// brings it back at the same address. Returns the child's endpoint.
    pub fn kill(&mut self, target: KillTarget, index: usize) -> Result<String> {
        let slot = self
            .slot_mut(target, index)
            .with_context(|| format!("no supervised {}:{index}", target.name()))?;
        if let Some(mut p) = slot.proc.take() {
            p.kill().ok();
            p.wait().ok();
        }
        let addr = slot.spec.addr.clone();
        trace_step("supervise", 0, &actor_of(&slot.spec), "killed", &addr);
        Ok(addr)
    }

    /// Respawn a killed/dead `(target, index)` child into its original
    /// endpoint slot and wait for it to accept connections. Returns the
    /// recovery time (kill-to-first-accepted-connection is the chaos
    /// rows' `recovery_ms` when the caller respawns immediately).
    pub fn respawn(&mut self, target: KillTarget, index: usize) -> Result<Duration> {
        let t0 = Instant::now();
        let bin_slot = self
            .slot_mut(target, index)
            .with_context(|| format!("no supervised {}:{index}", target.name()))?;
        if let Some(mut p) = bin_slot.proc.take() {
            // Defensive: never two children in one slot.
            p.kill().ok();
            p.wait().ok();
        }
        let spec = bin_slot.spec.clone();
        let proc = self.launch(&spec)?;
        let slot = self.slot_mut(target, index).expect("slot vanished");
        slot.proc = Some(proc);
        slot.restarts += 1;
        await_endpoint(&spec.addr)
            .with_context(|| format!("respawned {} never became ready", actor_of(&spec)))?;
        let dt = t0.elapsed();
        trace_step(
            "supervise",
            0,
            &actor_of(&spec),
            "respawned",
            &format!("{} in {:.1}ms", spec.addr, dt.as_secs_f64() * 1e3),
        );
        Ok(dt)
    }

    /// Sweep every slot with `try_wait`; any child that exited on its
    /// own is respawned into its slot. Returns the `(target, index)`
    /// pairs that were restarted — the caller decides whether state
    /// re-seeding is needed (PS shards) or the child self-recovers from
    /// its log (provDB shards).
    pub fn reap_and_restart(&mut self) -> Result<Vec<(KillTarget, usize)>> {
        let mut dead = Vec::new();
        for s in &mut self.slots {
            if let Some(p) = &mut s.proc {
                if p.try_wait().context("polling child")?.is_some() {
                    s.proc = None;
                    dead.push((s.spec.target, s.spec.index));
                    trace_step("supervise", 0, &actor_of(&s.spec), "exited", &s.spec.addr);
                }
            }
        }
        for &(t, i) in &dead {
            self.respawn(t, i)?;
        }
        Ok(dead)
    }

    /// Whether the `(target, index)` child is currently running.
    pub fn is_alive(&mut self, target: KillTarget, index: usize) -> bool {
        match self.slot_mut(target, index) {
            Some(Slot { proc: Some(p), .. }) => matches!(p.try_wait(), Ok(None)),
            _ => false,
        }
    }

    /// Restart count of the `(target, index)` slot.
    pub fn restarts(&self, target: KillTarget, index: usize) -> u64 {
        self.slots
            .iter()
            .find(|s| s.spec.target == target && s.spec.index == index)
            .map_or(0, |s| s.restarts)
    }

    /// Endpoint address of the `(target, index)` slot.
    pub fn addr_of(&self, target: KillTarget, index: usize) -> Option<&str> {
        self.slots
            .iter()
            .find(|s| s.spec.target == target && s.spec.index == index)
            .map(|s| s.spec.addr.as_str())
    }

    /// Chaos-plane checkpoint of one PS stat shard: the non-destructive
    /// keyed dump (`KIND_EXTRACT`) the restart path re-seeds from.
    pub fn ps_extract(
        &self,
        index: usize,
        shards: usize,
    ) -> Result<Vec<(crate::ps::FuncKey, crate::stats::RunStats)>> {
        let addr = self
            .addr_of(KillTarget::PsShard, index)
            .with_context(|| format!("no supervised ps:{index}"))?;
        let mut w = crate::ps::net::ShardWire::dial(addr, index as u32, shards as u32)?;
        w.extract()
    }

    /// Re-seed a (freshly respawned) PS stat shard with a checkpoint
    /// taken by [`ps_extract`](Self::ps_extract).
    pub fn ps_install(
        &self,
        index: usize,
        shards: usize,
        entries: &[(crate::ps::FuncKey, crate::stats::RunStats)],
    ) -> Result<()> {
        let addr = self
            .addr_of(KillTarget::PsShard, index)
            .with_context(|| format!("no supervised ps:{index}"))?;
        let mut w = crate::ps::net::ShardWire::dial(addr, index as u32, shards as u32)?;
        w.install(entries)
    }

    /// Kill every remaining child (idempotent; also runs on drop).
    pub fn stop_all(&mut self) {
        for s in &mut self.slots {
            if let Some(mut p) = s.proc.take() {
                p.kill().ok();
                p.wait().ok();
            }
        }
    }

    fn slot_mut(&mut self, target: KillTarget, index: usize) -> Option<&mut Slot> {
        self.slots.iter_mut().find(|s| s.spec.target == target && s.spec.index == index)
    }
}

impl Drop for Supervisor {
    fn drop(&mut self) {
        self.stop_all();
    }
}

fn actor_of(spec: &ChildSpec) -> String {
    format!("{}:{}", spec.target.name(), spec.index)
}

/// Poll `addr` with TCP connects until it accepts or the timeout lapses.
fn await_endpoint(addr: &str) -> Result<()> {
    let deadline = Instant::now() + READY_TIMEOUT;
    loop {
        match TcpStream::connect(addr) {
            Ok(_) => return Ok(()),
            Err(e) if Instant::now() >= deadline => {
                return Err(anyhow::anyhow!("endpoint {addr} not ready: {e}"));
            }
            Err(_) => std::thread::sleep(READY_POLL),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn child_specs_replay_their_slots() {
        let ps = ChildSpec::ps_shard(2, 4, "127.0.0.1:7001");
        assert_eq!(ps.target, KillTarget::PsShard);
        assert_eq!(ps.args[0], "ps-shard-server");
        assert!(ps.args.contains(&"--shard-id".to_string()));
        assert!(ps.args.contains(&"2".to_string()));
        let pd = ChildSpec::provdb(0, 2, "127.0.0.1:7002", std::path::Path::new("/tmp/x"));
        assert_eq!(pd.target, KillTarget::ProvDb);
        assert!(pd.args.contains(&"/tmp/x".to_string()));
        let ag = ChildSpec::agg_node(1, "127.0.0.1:7003", 0, 8);
        assert_eq!(ag.target, KillTarget::AggNode);
        assert!(ag.args.contains(&"--rank-hi".to_string()));
    }

    #[test]
    fn pick_addr_yields_loopback_ports() {
        let a = pick_addr().unwrap();
        let b = pick_addr().unwrap();
        assert!(a.starts_with("127.0.0.1:"));
        assert_ne!(a, b, "two picks must not collide while both unbound");
    }

    #[test]
    fn await_endpoint_accepts_a_live_listener() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap().to_string();
        await_endpoint(&addr).unwrap();
    }

    // Live spawn/kill/respawn of real server children is covered by
    // `tests/chaos.rs` (needs the built `chimbuko` binary).
}
