//! Workflow topology: which application and grammar each simulated MPI
//! rank runs. The paper's case study is a two-app workflow (NWChem MD
//! simulation + in-situ analysis); we split the global rank space the same
//! way — the bulk on the simulation, the rest on analysis.

use crate::config::Config;
use crate::trace::event::FuncRegistry;
use crate::trace::gen::CallGrammar;
use crate::trace::nwchem::{self, InjectionConfig};

/// One rank's assignment.
#[derive(Clone, Debug)]
pub struct RankAssignment {
    /// Global rank id (0-based over the whole workflow).
    pub rank: u32,
    /// Application index.
    pub app: u32,
    /// Rank within the application (used for anomaly-rank predicates).
    pub app_rank: u32,
}

/// The resolved workflow: grammars + registries per app, rank assignments.
pub struct Workflow {
    pub grammars: Vec<CallGrammar>,
    pub registries: Vec<FuncRegistry>,
    pub assignments: Vec<RankAssignment>,
    /// Injection configuration used (recorded in provenance metadata).
    pub injection: InjectionConfig,
}

impl Workflow {
    /// Build the NWChem-MD workflow from a config.
    ///
    /// App 0 (simulation) gets ⌈7/8⌉ of the ranks, app 1 (analysis) the
    /// rest (≥ 1 when `apps == 2`). `calls_per_step` maps to root
    /// iterations per step (one MD_NEWTON ≈ 26 function events filtered).
    pub fn nwchem(cfg: &Config) -> Workflow {
        Self::nwchem_with_injection(cfg, InjectionConfig::default())
    }

    /// Same, with explicit anomaly-injection rates (experiments use this).
    pub fn nwchem_with_injection(cfg: &Config, injection: InjectionConfig) -> Workflow {
        // ~26 filtered function events per MD_NEWTON iteration.
        let iters = (cfg.calls_per_step / 26).max(1) as u32;
        let (g_md, r_md) = nwchem::md_grammar(iters, &injection);
        let (g_an, r_an) = nwchem::analysis_grammar(iters);

        let mut assignments = Vec::with_capacity(cfg.ranks);
        if cfg.apps <= 1 {
            for rank in 0..cfg.ranks as u32 {
                assignments.push(RankAssignment { rank, app: 0, app_rank: rank });
            }
        } else {
            // App 1 gets every 8th rank (at least one).
            let analysis_every = 8;
            let mut app_rank = [0u32; 2];
            for rank in 0..cfg.ranks as u32 {
                let app = if cfg.ranks >= 2 && rank % analysis_every == analysis_every - 1 {
                    1
                } else {
                    0
                };
                assignments.push(RankAssignment { rank, app, app_rank: app_rank[app as usize] });
                app_rank[app as usize] += 1;
            }
            // Guarantee at least one analysis rank.
            if app_rank[1] == 0 {
                let last = assignments.last_mut().unwrap();
                last.app = 1;
                last.app_rank = 0;
            }
        }

        Workflow {
            grammars: vec![g_md, g_an],
            registries: vec![r_md, r_an],
            assignments,
            injection,
        }
    }

    /// Number of ranks assigned to `app`.
    pub fn ranks_of_app(&self, app: u32) -> usize {
        self.assignments.iter().filter(|a| a.app == app).count()
    }

    /// World size (ranks within the app — comm partners are app-local).
    pub fn app_world(&self, app: u32) -> u32 {
        self.ranks_of_app(app) as u32
    }

    /// Largest function table across apps (must fit artifact capacity).
    pub fn max_funcs(&self) -> usize {
        self.registries.iter().map(|r| r.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(ranks: usize, apps: usize) -> Config {
        Config { ranks, apps, ..Config::default() }
    }

    #[test]
    fn two_app_split_covers_all_ranks() {
        let w = Workflow::nwchem(&cfg(64, 2));
        assert_eq!(w.assignments.len(), 64);
        assert_eq!(w.ranks_of_app(0) + w.ranks_of_app(1), 64);
        assert!(w.ranks_of_app(1) >= 1);
        assert!(w.ranks_of_app(0) > w.ranks_of_app(1));
        // app_rank is dense per app.
        for app in 0..2u32 {
            let mut ids: Vec<u32> = w
                .assignments
                .iter()
                .filter(|a| a.app == app)
                .map(|a| a.app_rank)
                .collect();
            ids.sort();
            assert_eq!(ids, (0..ids.len() as u32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn single_app_gets_everything() {
        let w = Workflow::nwchem(&cfg(8, 1));
        assert_eq!(w.ranks_of_app(0), 8);
        assert_eq!(w.ranks_of_app(1), 0);
    }

    #[test]
    fn tiny_workflow_still_has_analysis_rank() {
        let w = Workflow::nwchem(&cfg(2, 2));
        assert_eq!(w.ranks_of_app(1), 1);
    }

    #[test]
    fn function_capacity_fits_default_artifact() {
        let w = Workflow::nwchem(&cfg(16, 2));
        assert!(w.max_funcs() <= 64, "max funcs {}", w.max_funcs());
    }

    #[test]
    fn iterations_scale_with_calls_per_step() {
        let mut c = cfg(4, 1);
        c.calls_per_step = 520;
        let w = Workflow::nwchem(&c);
        assert_eq!(w.grammars[0].iters_per_step, 20);
        c.calls_per_step = 5;
        let w = Workflow::nwchem(&c);
        assert_eq!(w.grammars[0].iters_per_step, 1);
    }
}
