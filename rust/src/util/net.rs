//! Shared TCP transport substrate for the service front-ends
//! ([`ps::net`](crate::ps::net), [`provdb::net`](crate::provdb::net), the
//! viz HTTP server): a poll(2)-driven **reactor** on the server side and
//! the auto-reconnect/backoff + connection-multiplexing wrappers on the
//! client side.
//!
//! * [`serve_reactor`] — bind, then drive every connection from a small
//!   fixed pool of event-loop threads ([`ReactorOpts::threads`], not one
//!   thread per client): nonblocking sockets, readiness from `poll(2)`
//!   (idle loops **block** — no sleep-polling), per-connection read/write
//!   buffer state machines, cooperative shutdown via [`TcpServerHandle`].
//!   A [`ConnDriver`] consumes raw bytes (the viz HTTP server); framed
//!   protocols layer a [`FrameHandler`] on top via [`serve_frames`],
//!   which parses [`wire`](crate::util::wire) frames, multiplexes logical
//!   streams, and applies **admission control**: a connection whose reply
//!   backlog exceeds [`ReactorOpts::conn_queue_bytes`] (or a server whose
//!   total backlog exceeds [`ReactorOpts::server_queue_bytes`]) answers
//!   further requests with a `Busy` control frame instead of queueing
//!   unboundedly, and the shed is counted on [`NetStats`].
//! * [`Reconnector`] — wraps a connection `C` plus the recipe to redial
//!   it. A failed operation drops the connection; the next use redials
//!   after a capped, **jittered** exponential cooldown, so one peer
//!   restart never permanently strands a client and mass-shed clients do
//!   not reconnect in synchronized waves. `Busy`-shed requests are the
//!   exception: the server is alive, so [`Reconnector::with`] keeps the
//!   connection and retries in-call under a bounded budget
//!   ([`BUSY_RETRY_BUDGET`]) with jittered pauses, counting retries and
//!   budget exhaustion on an attached [`NetStats`].
//! * [`MuxCore`] — the client half of stream multiplexing: several
//!   logical request/reply streams (a driver's conn-pool slots) share
//!   one socket, with replies demultiplexed to the stream that asked.
//!
//! Framing stays in [`wire`](crate::util::wire); this module is about
//! connection lifecycle and scheduling.

use crate::util::wire;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, Weak};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// poll(2) / setrlimit(2) via hand-declared FFI (the offline registry carries
// no libc crate; these are the only two syscall surfaces the reactor needs).

#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

#[repr(C)]
struct RLimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn poll(fds: *mut PollFd, nfds: u64, timeout: i32) -> i32;
    fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLERR: i16 = 0x008;
const POLLHUP: i16 = 0x010;
const POLLNVAL: i16 = 0x020;
const RLIMIT_NOFILE: i32 = 7;

/// Best-effort raise of the open-file soft limit to `min(hard, want)`.
/// The 10k-connection sweep needs ~2 fds per client; default soft limits
/// (often 1024) would otherwise cap the experiment. Returns the soft
/// limit in effect afterwards.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 0;
    }
    let target = want.min(lim.max);
    if target > lim.cur {
        let new = RLimit { cur: target, max: lim.max };
        if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
            return target;
        }
    }
    lim.cur
}

// ---------------------------------------------------------------------------
// Reactor configuration and counters.

/// Reactor sizing and backpressure bounds. All servers share the same
/// knobs (`[net]` config section: `net.reactor_threads`,
/// `net.conn_queue_bytes`, `net.server_queue_bytes`).
#[derive(Clone, Copy, Debug)]
pub struct ReactorOpts {
    /// Event-loop threads per server. Thread 0 owns the listener;
    /// accepted connections round-robin across loops.
    pub threads: usize,
    /// Soft per-connection reply-backlog bound, bytes: above this, new
    /// requests on the connection are shed with a `Busy` control frame
    /// instead of being processed.
    pub conn_queue_bytes: usize,
    /// Hard per-connection bound, bytes: a backlog above this drops the
    /// connection outright (counted in [`NetStats::dropped`]). Sized so
    /// a single maximal reply to a merely-slow reader never trips it.
    pub conn_hard_bytes: usize,
    /// Server-wide reply-backlog budget, bytes, summed across
    /// connections: above this every connection sheds until the backlog
    /// drains.
    pub server_queue_bytes: usize,
}

impl ReactorOpts {
    /// Build from the config-surfaced knobs; the hard per-connection
    /// bound is derived (soft bound, plus one maximal message, plus the
    /// soft bound again as slack for `Busy` frames).
    pub fn new(threads: usize, conn_queue_bytes: usize, server_queue_bytes: usize) -> ReactorOpts {
        ReactorOpts {
            threads: threads.max(1),
            conn_queue_bytes,
            conn_hard_bytes: conn_queue_bytes * 2 + wire::MAX_MSG,
            server_queue_bytes,
        }
    }
}

impl Default for ReactorOpts {
    fn default() -> ReactorOpts {
        ReactorOpts::new(2, 1 << 20, 64 << 20)
    }
}

/// Monotonic transport counters for one server, shared between the event
/// loops and whoever surfaces them (`/api/ps_stats`, provDB stats, the
/// connection sweep). Created by the *caller* of [`serve_reactor`] /
/// [`serve_frames`] so protocol handlers can stamp them into replies.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted / closed over the server's lifetime.
    pub accepted: AtomicU64,
    pub closed: AtomicU64,
    /// Frames parsed in / written out ([`serve_frames`] servers only).
    pub frames_in: AtomicU64,
    pub frames_out: AtomicU64,
    /// Requests answered with `Busy` instead of being processed.
    pub shed: AtomicU64,
    /// Client side of shedding: `Busy` replies a [`Reconnector`] retried
    /// in-call under its budget, and calls that ran the budget dry and
    /// surfaced the busy error to the caller.
    pub busy_retries: AtomicU64,
    pub busy_exhausted: AtomicU64,
    /// Connections dropped for exceeding the hard backlog bound.
    pub dropped: AtomicU64,
    /// Operations that failed over an *established* connection, whose
    /// in-flight request/reply state was abandoned by the reconnect path
    /// (counted in [`Reconnector::with`]). The chaos suite's
    /// bounded-loss accounting sums this with the server-side shed/drop
    /// counters — a crash may lose in-flight work, but never silently.
    pub inflight_lost: AtomicU64,
    /// Current unflushed reply bytes summed across connections (gauge).
    pub queue_bytes: AtomicU64,
    /// High-water mark of `queue_bytes`.
    pub queue_peak: AtomicU64,
    /// poll(2) returns across all loops — a blocked idle server holds
    /// this flat (the regression guard for the old 5 ms sleep-poll).
    pub wakeups: AtomicU64,
    /// Event-loop thread count (fixed at serve time).
    pub reactor_threads: AtomicU64,
}

impl NetStats {
    pub fn new() -> Arc<NetStats> {
        Arc::new(NetStats::default())
    }

    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::Relaxed)
    }

    pub fn dropped_count(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn busy_retry_count(&self) -> u64 {
        self.busy_retries.load(Ordering::Relaxed)
    }

    pub fn busy_exhausted_count(&self) -> u64 {
        self.busy_exhausted.load(Ordering::Relaxed)
    }

    pub fn inflight_lost_count(&self) -> u64 {
        self.inflight_lost.load(Ordering::Relaxed)
    }

    /// Current server-wide reply backlog, bytes.
    pub fn queue_depth(&self) -> u64 {
        self.queue_bytes.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Drivers: per-connection protocol state machines.

/// Per-connection byte-level protocol driver. The reactor calls
/// [`on_data`](Self::on_data) after appending newly-read bytes to
/// `inbuf`; the driver consumes what it can parse (draining the
/// consumed prefix) and appends reply bytes to `out`, which the reactor
/// flushes as the socket allows. Return `false` to close the connection
/// once `out` has flushed.
pub trait ConnDriver: Send {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, out: &mut Vec<u8>) -> bool;
}

/// Frame-level protocol handler layered over [`FrameDriver`] by
/// [`serve_frames`]: one call per complete, admitted wire frame. Replies
/// go through the [`FrameSink`], tagged with the stream they answer.
/// Return `false` to drop the connection (malformed input).
pub trait FrameHandler: Send {
    fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool;
}

/// Reply sink handed to [`FrameHandler::on_frame`]; frames are queued on
/// the connection's write buffer and counted.
pub struct FrameSink<'a> {
    out: &'a mut Vec<u8>,
    frames_out: &'a AtomicU64,
}

impl FrameSink<'_> {
    /// Queue one reply frame on `stream`.
    pub fn send(&mut self, stream: u32, payload: &[u8]) {
        self.frames_out.fetch_add(1, Ordering::Relaxed);
        push_frame(self.out, stream, payload);
    }
}

/// Append one wire frame to a buffer (the buffered-writer twin of
/// [`wire::write_frame`]).
fn push_frame(out: &mut Vec<u8>, stream: u32, payload: &[u8]) {
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&stream.to_le_bytes());
    out.extend_from_slice(payload);
}

/// [`ConnDriver`] adapter that parses wire frames, enforces admission
/// control (shedding with `Busy` when the connection or server backlog
/// bound is exceeded), and dispatches admitted frames to a
/// [`FrameHandler`].
pub struct FrameDriver<H: FrameHandler> {
    handler: H,
    stats: Arc<NetStats>,
    opts: ReactorOpts,
}

impl<H: FrameHandler> FrameDriver<H> {
    pub fn new(handler: H, stats: Arc<NetStats>, opts: ReactorOpts) -> FrameDriver<H> {
        FrameDriver { handler, stats, opts }
    }
}

impl<H: FrameHandler> ConnDriver for FrameDriver<H> {
    fn on_data(&mut self, inbuf: &mut Vec<u8>, out: &mut Vec<u8>) -> bool {
        // Chaos seam: an installed FaultPlan can sever this connection or
        // stall the read path before any parsing (one relaxed load when
        // chaos is off, the production default).
        match crate::util::fault::read_fault() {
            crate::util::fault::ReadFault::Sever => {
                inbuf.clear();
                return false;
            }
            crate::util::fault::ReadFault::Stall(d) => std::thread::sleep(d),
            crate::util::fault::ReadFault::None => {}
        }
        let mut consumed = 0usize;
        let mut keep = true;
        while keep && inbuf.len() - consumed >= wire::FRAME_HEADER {
            let len =
                u32::from_le_bytes(inbuf[consumed..consumed + 4].try_into().expect("4 bytes"))
                    as usize;
            if len > wire::MAX_MSG {
                keep = false;
                break;
            }
            let stream = u32::from_le_bytes(
                inbuf[consumed + 4..consumed + 8].try_into().expect("4 bytes"),
            );
            if inbuf.len() - consumed - wire::FRAME_HEADER < len {
                break; // incomplete frame; wait for more bytes
            }
            let start = consumed + wire::FRAME_HEADER;
            consumed = start + len;
            if stream & wire::CTRL_BIT != 0 {
                keep = false; // control frames are server-to-client only
                break;
            }
            self.stats.frames_in.fetch_add(1, Ordering::Relaxed);
            let over_conn = out.len() > self.opts.conn_queue_bytes;
            let over_server = self.stats.queue_bytes.load(Ordering::Relaxed)
                > self.opts.server_queue_bytes as u64;
            if over_conn || over_server {
                self.stats.shed.fetch_add(1, Ordering::Relaxed);
                push_frame(out, stream | wire::CTRL_BIT, &[wire::CTRL_BUSY]);
                continue;
            }
            // Chaos seam: delay this reply (the handler runs after the
            // pause, so its reply reaches the wire late).
            if let Some(d) = crate::util::fault::reply_delay() {
                std::thread::sleep(d);
            }
            let payload = &inbuf[start..start + len];
            let mut sink = FrameSink { out, frames_out: &self.stats.frames_out };
            keep = self.handler.on_frame(stream, payload, &mut sink);
        }
        inbuf.drain(..consumed);
        keep
    }
}

// ---------------------------------------------------------------------------
// The reactor itself.

/// Handle to a running reactor server; [`stop`](Self::stop) (or drop)
/// shuts the listener down **and severs every live connection** (so
/// stopping a server actually looks like a killed process to its peers —
/// the behaviour the reconnect tests rely on), then joins the event-loop
/// threads. The port is free for rebinding when `stop` returns.
pub struct TcpServerHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<HashMap<u64, TcpStream>>>,
    stats: Arc<NetStats>,
    wakers: Vec<UnixStream>,
    joins: Vec<std::thread::JoinHandle<()>>,
}

impl TcpServerHandle {
    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The server's transport counters.
    pub fn stats(&self) -> &Arc<NetStats> {
        &self.stats
    }

    /// Stop accepting, sever live connections, and join the event-loop
    /// threads. The port is free for rebinding when this returns.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for w in &mut self.wakers {
            let _ = w.write(&[1u8]);
        }
        for (_, s) in self.conns.lock().expect("conn registry lock").iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for TcpServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind `addr` and serve connections on [`ReactorOpts::threads`]
/// event-loop threads named `name-<i>`. Each accepted connection gets a
/// fresh driver from `factory` and lives on one loop for its lifetime.
/// `stats` is caller-created so protocol handlers can surface it.
pub fn serve_reactor(
    name: &str,
    addr: &str,
    opts: ReactorOpts,
    stats: Arc<NetStats>,
    factory: impl Fn() -> Box<dyn ConnDriver> + Send + Sync + 'static,
) -> Result<TcpServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let threads = opts.threads.max(1);
    stats.reactor_threads.store(threads as u64, Ordering::Relaxed);
    let stop = Arc::new(AtomicBool::new(false));
    let registry: Arc<Mutex<HashMap<u64, TcpStream>>> = Arc::new(Mutex::new(HashMap::new()));
    let factory: Arc<dyn Fn() -> Box<dyn ConnDriver> + Send + Sync> = Arc::new(factory);

    // One self-wake pipe + injection queue per loop; thread 0 keeps write
    // ends for all of them to hand off accepted connections.
    let mut wakers = Vec::with_capacity(threads);
    let mut mates = Vec::with_capacity(threads);
    let mut loop_ends = Vec::with_capacity(threads);
    for _ in 0..threads {
        let (tx, rx) = UnixStream::pair().context("reactor wake pipe")?;
        tx.set_nonblocking(true)?;
        rx.set_nonblocking(true)?;
        let inject: Arc<Mutex<Vec<(u64, TcpStream)>>> = Arc::new(Mutex::new(Vec::new()));
        mates.push((inject.clone(), tx.try_clone().context("waker clone")?));
        wakers.push(tx);
        loop_ends.push((rx, inject));
    }

    let mut listener = Some(listener);
    let mut mates = Some(mates);
    let mut joins = Vec::with_capacity(threads);
    for (t, (wake_rx, inject)) in loop_ends.into_iter().enumerate() {
        let lst = if t == 0 { listener.take() } else { None };
        let my_mates = if t == 0 { mates.take().expect("mates for loop 0") } else { Vec::new() };
        let stop = stop.clone();
        let stats = stats.clone();
        let registry = registry.clone();
        let factory = factory.clone();
        joins.push(
            std::thread::Builder::new().name(format!("{name}-{t}")).spawn(move || {
                event_loop(EventLoop {
                    me: t,
                    threads,
                    stop,
                    stats,
                    registry,
                    wake_rx,
                    inject,
                    listener: lst,
                    mates: my_mates,
                    factory,
                    opts,
                })
            })?,
        );
    }
    Ok(TcpServerHandle { addr: local, stop, conns: registry, stats, wakers, joins })
}

/// [`serve_reactor`] for framed protocols: each connection gets a fresh
/// [`FrameHandler`] from `factory`, wrapped in the admission-controlled
/// [`FrameDriver`].
pub fn serve_frames<H: FrameHandler + 'static>(
    name: &str,
    addr: &str,
    opts: ReactorOpts,
    stats: Arc<NetStats>,
    factory: impl Fn() -> H + Send + Sync + 'static,
) -> Result<TcpServerHandle> {
    let fstats = stats.clone();
    serve_reactor(name, addr, opts, stats, move || {
        Box::new(FrameDriver::new(factory(), fstats.clone(), opts))
    })
}

/// One connection's state on its event loop.
struct Conn {
    id: u64,
    stream: TcpStream,
    driver: Box<dyn ConnDriver>,
    inbuf: Vec<u8>,
    out: Vec<u8>,
    out_pos: usize,
    /// No further reads; flush `out`, then close.
    closing: bool,
    /// Backlog bytes currently counted in the server-wide gauge.
    charged: usize,
}

impl Conn {
    fn new(id: u64, stream: TcpStream, driver: Box<dyn ConnDriver>) -> Conn {
        Conn {
            id,
            stream,
            driver,
            inbuf: Vec::new(),
            out: Vec::new(),
            out_pos: 0,
            closing: false,
            charged: 0,
        }
    }

    fn backlog(&self) -> usize {
        self.out.len() - self.out_pos
    }
}

struct EventLoop {
    me: usize,
    threads: usize,
    stop: Arc<AtomicBool>,
    stats: Arc<NetStats>,
    registry: Arc<Mutex<HashMap<u64, TcpStream>>>,
    wake_rx: UnixStream,
    inject: Arc<Mutex<Vec<(u64, TcpStream)>>>,
    listener: Option<TcpListener>,
    /// Loop 0 only: every loop's (injection queue, waker) for round-robin
    /// connection hand-off.
    mates: Vec<(Arc<Mutex<Vec<(u64, TcpStream)>>>, UnixStream)>,
    factory: Arc<dyn Fn() -> Box<dyn ConnDriver> + Send + Sync>,
    opts: ReactorOpts,
}

fn event_loop(mut lp: EventLoop) {
    let mut conns: Vec<Conn> = Vec::new();
    let mut pollfds: Vec<PollFd> = Vec::new();
    let mut scratch = vec![0u8; 64 * 1024];
    let mut next_id: u64 = lp.me as u64; // only loop 0 accepts; ids stay unique anyway
    loop {
        pollfds.clear();
        pollfds.push(PollFd { fd: lp.wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        let has_listener = lp.listener.is_some();
        if let Some(l) = &lp.listener {
            pollfds.push(PollFd { fd: l.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        let base = 1 + usize::from(has_listener);
        for c in &conns {
            let mut ev = 0i16;
            if !c.closing {
                ev |= POLLIN;
            }
            if c.backlog() > 0 {
                ev |= POLLOUT;
            }
            pollfds.push(PollFd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
        }
        // Block until something is ready — an idle server makes no
        // syscalls (the accept loop used to sleep-poll every 5 ms).
        let rc = unsafe { poll(pollfds.as_mut_ptr(), pollfds.len() as u64, -1) };
        if rc < 0 {
            if std::io::Error::last_os_error().kind() == std::io::ErrorKind::Interrupted {
                continue;
            }
            break;
        }
        lp.stats.wakeups.fetch_add(1, Ordering::Relaxed);

        // 1. Wake pipe: drain it, then honor stop / adopt injected conns.
        if pollfds[0].revents != 0 {
            loop {
                match lp.wake_rx.read(&mut scratch[..64]) {
                    Ok(n) if n == 64 => {}
                    _ => break,
                }
            }
        }
        if lp.stop.load(Ordering::Relaxed) {
            break;
        }
        {
            let mut q = lp.inject.lock().expect("inject queue");
            for (id, stream) in q.drain(..) {
                conns.push(Conn::new(id, stream, (lp.factory)()));
            }
        }

        // 2. Listener (loop 0): accept and round-robin across loops.
        if has_listener && pollfds[1].revents != 0 {
            loop {
                let l = lp.listener.as_ref().expect("listener on loop 0");
                match l.accept() {
                    Ok((s, _)) => {
                        let _ = s.set_nonblocking(true);
                        let _ = s.set_nodelay(true);
                        let id = next_id;
                        next_id += 1;
                        lp.stats.accepted.fetch_add(1, Ordering::Relaxed);
                        if let Ok(clone) = s.try_clone() {
                            lp.registry.lock().expect("conn registry lock").insert(id, clone);
                        }
                        let target = (id % lp.threads as u64) as usize;
                        if target == lp.me {
                            conns.push(Conn::new(id, s, (lp.factory)()));
                        } else {
                            let (q, waker) = &mut lp.mates[target];
                            q.lock().expect("inject queue").push((id, s));
                            let _ = waker.write(&[1u8]);
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => break,
                }
            }
        }

        // 3. Ready connections: read → drive → flush → account → reap.
        let mut dead: Vec<usize> = Vec::new();
        for i in 0..conns.len() {
            let re = pollfds[base + i].revents;
            if re == 0 {
                continue;
            }
            let c = &mut conns[i];
            let mut gone = false;
            if !c.closing && re & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0 {
                let mut eof = false;
                let mut got = false;
                loop {
                    match c.stream.read(&mut scratch) {
                        Ok(0) => {
                            eof = true;
                            break;
                        }
                        Ok(n) => {
                            got = true;
                            c.inbuf.extend_from_slice(&scratch[..n]);
                            if n < scratch.len() {
                                break;
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                        Err(_) => {
                            eof = true;
                            break;
                        }
                    }
                }
                if got && !c.driver.on_data(&mut c.inbuf, &mut c.out) {
                    c.closing = true;
                    c.inbuf.clear();
                }
                if eof {
                    c.closing = true;
                }
            }
            if !flush_out(c) {
                gone = true;
            }
            if c.backlog() > lp.opts.conn_hard_bytes {
                lp.stats.dropped.fetch_add(1, Ordering::Relaxed);
                gone = true;
            }
            recharge(c, &lp.stats);
            if c.closing && c.backlog() == 0 {
                gone = true;
            }
            if gone {
                dead.push(i);
            }
        }
        for &i in dead.iter().rev() {
            let c = conns.swap_remove(i);
            close_conn(c, &lp.stats, &lp.registry);
        }
    }
    // Shutdown: sever and account every connection this loop still owns.
    for c in conns.drain(..) {
        let _ = c.stream.shutdown(std::net::Shutdown::Both);
        close_conn(c, &lp.stats, &lp.registry);
    }
}

/// Write as much of `out` as the socket accepts; `false` on a fatal
/// write error. Fully-flushed buffers are reset; large flushed prefixes
/// are compacted so a long-lived backlog can't pin memory.
fn flush_out(c: &mut Conn) -> bool {
    while c.out_pos < c.out.len() {
        match c.stream.write(&c.out[c.out_pos..]) {
            Ok(0) => return false,
            Ok(n) => c.out_pos += n,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return false,
        }
    }
    if c.out_pos >= c.out.len() {
        c.out.clear();
        c.out_pos = 0;
    } else if c.out_pos >= 64 * 1024 {
        c.out.drain(..c.out_pos);
        c.out_pos = 0;
    }
    true
}

/// Reconcile the connection's backlog with the server-wide gauge.
fn recharge(c: &mut Conn, stats: &NetStats) {
    let backlog = c.backlog();
    if backlog > c.charged {
        let grown = (backlog - c.charged) as u64;
        let now = stats.queue_bytes.fetch_add(grown, Ordering::Relaxed) + grown;
        stats.queue_peak.fetch_max(now, Ordering::Relaxed);
    } else if backlog < c.charged {
        stats.queue_bytes.fetch_sub((c.charged - backlog) as u64, Ordering::Relaxed);
    }
    c.charged = backlog;
}

fn close_conn(mut c: Conn, stats: &NetStats, registry: &Mutex<HashMap<u64, TcpStream>>) {
    c.out.clear();
    c.out_pos = 0;
    recharge(&mut c, stats);
    stats.closed.fetch_add(1, Ordering::Relaxed);
    registry.lock().expect("conn registry lock").remove(&c.id);
}

// ---------------------------------------------------------------------------
// Client-side stream multiplexing.

/// The shared client half of one multiplexed socket: several logical
/// streams (a conn-pool's slots) send concurrently under the write lock
/// and receive via leader/follower demultiplexing — whichever stream's
/// thread wins the read lock pulls frames, keeping its own and parking
/// foreign frames for their streams. Protocol layers guarantee at most
/// one outstanding request per stream (the pool's per-slot mutex), so a
/// stream's replies can't reorder among themselves.
pub struct MuxCore {
    wr: Mutex<TcpStream>,
    rd: Mutex<TcpStream>,
    pending: Mutex<HashMap<u32, VecDeque<MuxEvent>>>,
    cv: Condvar,
    dead: AtomicBool,
}

enum MuxEvent {
    Frame(Vec<u8>),
    Busy,
}

impl MuxCore {
    /// Adopt a freshly-dialed socket.
    pub fn new(stream: TcpStream) -> Result<Arc<MuxCore>> {
        let rd = stream.try_clone().context("mux read clone")?;
        Ok(Arc::new(MuxCore {
            wr: Mutex::new(stream),
            rd: Mutex::new(rd),
            pending: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            dead: AtomicBool::new(false),
        }))
    }

    /// Whether the socket has failed; a dead core is never revived —
    /// callers redial and replace it.
    pub fn is_dead(&self) -> bool {
        self.dead.load(Ordering::Relaxed)
    }

    fn mark_dead(&self) {
        self.dead.store(true, Ordering::Relaxed);
        self.cv.notify_all();
    }

    /// Send one request frame on `stream`.
    pub fn send(&self, stream: u32, payload: &[u8]) -> Result<()> {
        if self.is_dead() {
            bail!("mux connection is dead");
        }
        let mut w = self.wr.lock().expect("mux write lock");
        match wire::write_frame(&mut *w, stream, payload) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.mark_dead();
                Err(e)
            }
        }
    }

    /// Receive the next reply for `stream`. A `Busy` control frame for
    /// this stream becomes an error (the caller's `Reconnector` turns it
    /// into backoff); EOF or a read error kills the core for all streams.
    pub fn recv(&self, sid: u32) -> Result<Vec<u8>> {
        loop {
            {
                let mut p = self.pending.lock().expect("mux pending lock");
                if let Some(ev) = p.get_mut(&sid).and_then(|q| q.pop_front()) {
                    return deliver(ev, sid);
                }
                if self.is_dead() {
                    bail!("mux connection is dead");
                }
            }
            match self.rd.try_lock() {
                Ok(mut rd) => {
                    // Leader: pull exactly one frame, then re-loop (which
                    // releases the read lock between frames so another
                    // stream can take over).
                    let res = wire::read_frame(&mut *rd);
                    drop(rd);
                    match res {
                        Ok(Some((stream, payload))) => {
                            let (target, ev) = if stream & wire::CTRL_BIT != 0 {
                                if payload.first() != Some(&wire::CTRL_BUSY) {
                                    continue; // unknown control frame: ignore
                                }
                                (stream & !wire::CTRL_BIT, MuxEvent::Busy)
                            } else {
                                (stream, MuxEvent::Frame(payload))
                            };
                            if target == sid {
                                self.cv.notify_all();
                                return deliver(ev, sid);
                            }
                            let mut p = self.pending.lock().expect("mux pending lock");
                            p.entry(target).or_default().push_back(ev);
                            drop(p);
                            self.cv.notify_all();
                        }
                        Ok(None) | Err(_) => {
                            self.mark_dead();
                            bail!("mux connection closed");
                        }
                    }
                }
                Err(_) => {
                    // Follower: wait for the leader to park our frame.
                    // The timeout is a belt-and-braces retry, not a poll
                    // cadence — deliveries notify.
                    let p = self.pending.lock().expect("mux pending lock");
                    let _ = self
                        .cv
                        .wait_timeout(p, Duration::from_millis(20))
                        .expect("mux pending lock");
                }
            }
        }
    }

    /// One request/reply round-trip on `stream`.
    pub fn call(&self, stream: u32, payload: &[u8]) -> Result<Vec<u8>> {
        self.send(stream, payload)?;
        self.recv(stream)
    }
}

fn deliver(ev: MuxEvent, sid: u32) -> Result<Vec<u8>> {
    match ev {
        MuxEvent::Frame(b) => Ok(b),
        MuxEvent::Busy => bail!("server busy: stream {sid} request shed"),
    }
}

/// A registry slot holding the shared socket for one endpoint, so every
/// pool slot's redial closure can find (or replace) the live [`MuxCore`].
pub type MuxSlot = Arc<Mutex<Weak<MuxCore>>>;

/// Fresh, empty mux slot.
pub fn mux_slot() -> MuxSlot {
    Arc::new(Mutex::new(Weak::new()))
}

/// Get the endpooint's live shared core, dialing a fresh socket (and
/// replacing a dead one) if needed. `dial` runs under the slot lock, so
/// concurrent redials collapse into one socket.
pub fn mux_connect(slot: &MuxSlot, dial: impl FnOnce() -> Result<Arc<MuxCore>>) -> Result<Arc<MuxCore>> {
    let mut w = slot.lock().expect("mux slot lock");
    if let Some(core) = w.upgrade() {
        if !core.is_dead() {
            return Ok(core);
        }
    }
    let core = dial()?;
    *w = Arc::downgrade(&core);
    Ok(core)
}

// ---------------------------------------------------------------------------
// Reconnecting client wrapper.

/// Initial reconnect cooldown after a failure; doubles per consecutive
/// failure up to [`MAX_BACKOFF`], then jitters into `[d/2, d]`.
const INITIAL_BACKOFF: Duration = Duration::from_millis(50);
const MAX_BACKOFF: Duration = Duration::from_secs(2);

/// In-call retries [`Reconnector::with`] grants a request the server
/// answered with `Busy` before surfacing the error. Bounded so a
/// persistently-overloaded server turns into caller-visible degradation
/// (the PS router's partial replies) instead of an unbounded stall.
pub const BUSY_RETRY_BUDGET: u32 = 3;

/// Pause before re-sending a shed request; doubles per retry within one
/// call and is jittered into `[d/2, d]` like the reconnect cooldown, so
/// a herd of shed clients doesn't re-offer its load in one wave.
const BUSY_RETRY_PAUSE: Duration = Duration::from_millis(20);

/// A `Busy` control frame surfaces as an error whose chain carries the
/// wire layer's "server busy" text (see [`wire::read_msg`] and
/// [`MuxCore::recv`]); everything else is a transport failure.
fn is_busy_shed(e: &anyhow::Error) -> bool {
    e.chain().any(|c| c.to_string().contains("server busy"))
}

/// A connection that knows how to re-establish itself.
///
/// Operations run through [`with`](Self::with) (or the split
/// [`get`](Self::get)/[`fail`](Self::fail) pair when a caller pipelines
/// across several connections): an error drops the connection and starts
/// a capped exponential cooldown, and the next use redials. The cooldown
/// is jittered (uniform in `[d/2, d]`) so thousands of clients shed by
/// an overloaded server don't redial in synchronized waves. Callers
/// decide what a failed operation means (the PS router degrades the
/// affected shard's slice of a reply; the viz layer returns an empty
/// result) — the wrapper only guarantees the *connection* recovers.
pub struct Reconnector<C> {
    addr: String,
    connect: Box<dyn Fn(&str) -> Result<C> + Send>,
    conn: Option<C>,
    consecutive_failures: u32,
    retry_after: Option<Instant>,
    jitter: u64,
    stats: Option<Arc<NetStats>>,
}

impl<C> Reconnector<C> {
    /// Lazy: first use dials.
    pub fn new(addr: &str, connect: impl Fn(&str) -> Result<C> + Send + 'static) -> Self {
        Reconnector {
            addr: addr.to_string(),
            connect: Box::new(connect),
            conn: None,
            consecutive_failures: 0,
            retry_after: None,
            jitter: jitter_seed(addr),
            stats: None,
        }
    }

    /// Attach a counter sheet: busy retries and budget exhaustions in
    /// [`with`](Self::with) are tallied on it.
    pub fn with_stats(mut self, stats: Arc<NetStats>) -> Self {
        self.stats = Some(stats);
        self
    }

    /// Eager: dial now, fail fast on a bad address.
    pub fn connected(
        addr: &str,
        connect: impl Fn(&str) -> Result<C> + Send + 'static,
    ) -> Result<Self> {
        let conn = connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Ok(Self::seeded(addr, connect, conn))
    }

    /// Adopt an already-established connection (e.g. one a handshake was
    /// just read from) without redialing.
    pub fn seeded(
        addr: &str,
        connect: impl Fn(&str) -> Result<C> + Send + 'static,
        conn: C,
    ) -> Self {
        let mut r = Self::new(addr, connect);
        r.conn = Some(conn);
        r
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }

    /// Borrow the live connection, redialing if necessary. Within the
    /// cooldown window after a failure this returns an error immediately
    /// instead of hammering the peer.
    pub fn get(&mut self) -> Result<&mut C> {
        if self.conn.is_none() {
            if let Some(t) = self.retry_after {
                if Instant::now() < t {
                    bail!("reconnect to {} backing off", self.addr);
                }
            }
            match (self.connect)(&self.addr) {
                Ok(c) => {
                    self.conn = Some(c);
                    self.consecutive_failures = 0;
                    self.retry_after = None;
                }
                Err(e) => {
                    self.note_failure();
                    return Err(e.context(format!("reconnecting to {}", self.addr)));
                }
            }
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }

    /// Drop the connection after a failed operation; the next [`get`]
    /// redials once the cooldown elapses.
    pub fn fail(&mut self) {
        self.conn = None;
        self.note_failure();
    }

    fn note_failure(&mut self) {
        let shift = self.consecutive_failures.min(8);
        let base = INITIAL_BACKOFF.saturating_mul(1u32 << shift).min(MAX_BACKOFF);
        // Jitter uniformly into [base/2, base]: the backoff keeps its
        // lower bound (fast-fail guarantees hold) but a shed herd's
        // redials decorrelate instead of arriving in waves.
        let nanos = base.as_nanos() as u64;
        let delay = nanos / 2 + crate::util::rng::splitmix64(&mut self.jitter) % (nanos / 2 + 1);
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        self.retry_after = Some(Instant::now() + Duration::from_nanos(delay));
    }

    /// Run one operation against the (re)connected peer. A transport
    /// error drops the connection so the next call redials; a `Busy`
    /// shed keeps it (the server is alive, it declined the request) and
    /// retries in-call up to [`BUSY_RETRY_BUDGET`] times after a
    /// jittered, doubling pause, surfacing the busy error — and counting
    /// the exhaustion on any attached [`NetStats`] — once the budget
    /// runs dry.
    pub fn with<T>(&mut self, mut op: impl FnMut(&mut C) -> Result<T>) -> Result<T> {
        let mut busy_spent = 0u32;
        loop {
            let c = self.get()?;
            let err = match op(c) {
                Ok(v) => return Ok(v),
                Err(e) => e,
            };
            if !is_busy_shed(&err) {
                // The operation failed over an *established* connection:
                // whatever it had in flight is gone with the socket.
                // Count it so crash-window loss is bounded and auditable
                // (redial failures in `get` don't reach here — nothing
                // was in flight).
                if let Some(s) = &self.stats {
                    s.inflight_lost.fetch_add(1, Ordering::Relaxed);
                }
                self.fail();
                return Err(err);
            }
            if busy_spent >= BUSY_RETRY_BUDGET {
                if let Some(s) = &self.stats {
                    s.busy_exhausted.fetch_add(1, Ordering::Relaxed);
                }
                return Err(err.context(format!(
                    "{} still shedding after {BUSY_RETRY_BUDGET} busy retries",
                    self.addr
                )));
            }
            if let Some(s) = &self.stats {
                s.busy_retries.fetch_add(1, Ordering::Relaxed);
            }
            std::thread::sleep(self.busy_pause(busy_spent));
            busy_spent += 1;
        }
    }

    /// Jittered pause before retrying a shed request: doubles per
    /// attempt from [`BUSY_RETRY_PAUSE`], uniform in `[d/2, d]` off the
    /// same per-client jitter stream as the reconnect cooldown.
    fn busy_pause(&mut self, attempt: u32) -> Duration {
        let base = BUSY_RETRY_PAUSE
            .saturating_mul(1u32 << attempt.min(8))
            .min(MAX_BACKOFF);
        let nanos = base.as_nanos() as u64;
        let delay = nanos / 2 + crate::util::rng::splitmix64(&mut self.jitter) % (nanos / 2 + 1);
        Duration::from_nanos(delay)
    }
}

/// Deterministic-free jitter seed: per-process counter mixed with the
/// peer address, so every client (and every slot of one client) walks an
/// independent backoff sequence without consulting a clock.
fn jitter_seed(addr: &str) -> u64 {
    use std::hash::{Hash, Hasher};
    static COUNTER: AtomicU64 = AtomicU64::new(0x9E37_79B9_7F4A_7C15);
    let mut h = std::collections::hash_map::DefaultHasher::new();
    addr.hash(&mut h);
    COUNTER.fetch_add(0x9E37_79B9, Ordering::Relaxed).hash(&mut h);
    h.finish() | 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU32;

    struct EchoDriver;

    impl ConnDriver for EchoDriver {
        fn on_data(&mut self, inbuf: &mut Vec<u8>, out: &mut Vec<u8>) -> bool {
            out.extend_from_slice(inbuf);
            inbuf.clear();
            true
        }
    }

    struct EchoFrames;

    impl FrameHandler for EchoFrames {
        fn on_frame(&mut self, stream: u32, payload: &[u8], out: &mut FrameSink) -> bool {
            out.send(stream, payload);
            true
        }
    }

    fn echo_server(opts: ReactorOpts) -> (TcpServerHandle, Arc<NetStats>) {
        let stats = NetStats::new();
        let srv = serve_frames("test-frames", "127.0.0.1:0", opts, stats.clone(), || EchoFrames)
            .unwrap();
        (srv, stats)
    }

    #[test]
    fn serve_reactor_round_trip_and_stop() {
        let stats = NetStats::new();
        let mut srv = serve_reactor(
            "test-echo",
            "127.0.0.1:0",
            ReactorOpts::default(),
            stats.clone(),
            || Box::new(EchoDriver),
        )
        .unwrap();
        let mut c = TcpStream::connect(srv.addr()).unwrap();
        c.write_all(b"ping").unwrap();
        let mut b = [0u8; 4];
        c.read_exact(&mut b).unwrap();
        assert_eq!(&b, b"ping");
        assert_eq!(stats.accepted.load(Ordering::Relaxed), 1);
        srv.stop();
        // Severed on stop: the client sees EOF (or a reset).
        let mut rest = Vec::new();
        let _ = c.read_to_end(&mut rest);
        assert!(rest.is_empty());
    }

    #[test]
    fn idle_reactor_blocks_instead_of_polling() {
        let stats = NetStats::new();
        let mut srv = serve_reactor(
            "test-idle",
            "127.0.0.1:0",
            ReactorOpts::default(),
            stats.clone(),
            || Box::new(EchoDriver),
        )
        .unwrap();
        let mut c = TcpStream::connect(srv.addr()).unwrap();
        c.write_all(b"warm").unwrap();
        let mut b = [0u8; 4];
        c.read_exact(&mut b).unwrap();
        let before = stats.wakeups.load(Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(300));
        let woke = stats.wakeups.load(Ordering::Relaxed) - before;
        // The old accept loop slept 5 ms per spin — ~60 wakeups here.
        assert!(woke <= 2, "idle loops must block in poll(2), saw {woke} wakeups");
        srv.stop();
    }

    #[test]
    fn frame_server_demuxes_streams() {
        let (mut srv, stats) = echo_server(ReactorOpts::default());
        let s = TcpStream::connect(srv.addr()).unwrap();
        let core = MuxCore::new(s).unwrap();
        assert_eq!(core.call(1, b"one").unwrap(), b"one");
        assert_eq!(core.call(2, b"two").unwrap(), b"two");
        // Pipelined across streams: replies land on the stream that asked.
        core.send(3, b"three").unwrap();
        core.send(4, b"four").unwrap();
        assert_eq!(core.recv(4).unwrap(), b"four");
        assert_eq!(core.recv(3).unwrap(), b"three");
        assert_eq!(stats.frames_in.load(Ordering::Relaxed), 4);
        assert_eq!(stats.frames_out.load(Ordering::Relaxed), 4);
        srv.stop();
        assert!(core.call(1, b"x").is_err(), "severed socket must fail");
        assert!(core.is_dead());
    }

    #[test]
    fn overloaded_connection_sheds_with_busy() {
        // Tiny soft bound; replies echo the payload, so a client that
        // never drains trips it as soon as the kernel buffers fill.
        let opts = ReactorOpts::new(2, 64 * 1024, 1 << 30);
        let (mut srv, stats) = echo_server(opts);
        let mut flood = TcpStream::connect(srv.addr()).unwrap();
        let chunk = vec![7u8; 256 * 1024];
        for _ in 0..128 {
            wire::write_frame(&mut flood, 9, &chunk).unwrap(); // 32 MiB total, never reads
        }
        // Wait for the shed counter to move (the server is still healthy).
        let t0 = Instant::now();
        while stats.shed_count() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(stats.shed_count() > 0, "flood must trip the soft backlog bound");
        assert!(stats.queue_peak.load(Ordering::Relaxed) > 64 * 1024);
        // A well-behaved client on the same server is unaffected.
        let well = TcpStream::connect(srv.addr()).unwrap();
        let core = MuxCore::new(well).unwrap();
        assert_eq!(core.call(1, b"fine").unwrap(), b"fine");
        // The flooding client eventually reads Busy control frames.
        drop(core);
        let flood_core = MuxCore::new(flood.try_clone().unwrap()).unwrap();
        let mut saw_busy = false;
        for _ in 0..256 {
            match flood_core.recv(9) {
                Ok(_) => {}
                Err(e) => {
                    saw_busy = e.to_string().contains("busy");
                    break;
                }
            }
        }
        assert!(saw_busy, "shed requests must answer Busy on the request stream");
        srv.stop();
    }

    #[test]
    fn hard_backlog_bound_drops_the_connection() {
        let mut opts = ReactorOpts::new(1, 16 * 1024, 1 << 30);
        opts.conn_hard_bytes = 128 * 1024;
        let (mut srv, stats) = echo_server(opts);
        let mut flood = TcpStream::connect(srv.addr()).unwrap();
        let chunk = vec![3u8; 128 * 1024];
        // Keep writing until the server drops us (write fails) or we've
        // pushed far more than the kernel can cushion.
        for _ in 0..512 {
            if wire::write_frame(&mut flood, 1, &chunk).is_err() {
                break;
            }
        }
        let t0 = Instant::now();
        while stats.dropped_count() == 0 && t0.elapsed() < Duration::from_secs(10) {
            std::thread::sleep(Duration::from_millis(10));
        }
        assert!(stats.dropped_count() > 0, "hard bound must drop the connection");
        srv.stop();
    }

    #[test]
    fn malformed_frames_drop_the_connection_not_the_server() {
        let (mut srv, stats) = echo_server(ReactorOpts::default());
        // Oversized length prefix: dropped before any allocation.
        let mut bad = TcpStream::connect(srv.addr()).unwrap();
        bad.write_all(&(u32::MAX).to_le_bytes()).unwrap();
        bad.write_all(&0u32.to_le_bytes()).unwrap();
        let mut rest = Vec::new();
        let _ = bad.read_to_end(&mut rest);
        assert!(rest.is_empty(), "malformed conn must be severed without a reply");
        // A control frame from a client is equally malformed.
        let mut bad = TcpStream::connect(srv.addr()).unwrap();
        wire::write_frame(&mut bad, wire::CTRL_BIT | 3, b"nope").unwrap();
        let mut rest = Vec::new();
        let _ = bad.read_to_end(&mut rest);
        assert!(rest.is_empty());
        // The server is still serving.
        let core = MuxCore::new(TcpStream::connect(srv.addr()).unwrap()).unwrap();
        assert_eq!(core.call(0, b"ok").unwrap(), b"ok");
        assert!(stats.closed.load(Ordering::Relaxed) >= 2);
        srv.stop();
    }

    #[test]
    fn reconnector_redials_after_failure() {
        let dials = Arc::new(AtomicU32::new(0));
        let d2 = dials.clone();
        let mut r: Reconnector<u32> =
            Reconnector::new("nowhere", move |_| Ok(d2.fetch_add(1, Ordering::Relaxed) + 1));
        assert!(!r.is_connected());
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        assert!(r.is_connected());
        // Same connection reused while healthy.
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        // A failed op drops the connection and starts the cooldown…
        assert!(r.with(|_| -> Result<()> { anyhow::bail!("boom") }).is_err());
        assert!(!r.is_connected());
        // …so an immediate retry is refused without dialing…
        assert!(r.get().is_err());
        assert_eq!(dials.load(Ordering::Relaxed), 1);
        // …and after the cooldown the next use redials.
        std::thread::sleep(INITIAL_BACKOFF * 3);
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 2);
        assert_eq!(dials.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn reconnector_connect_failures_back_off() {
        let mut r: Reconnector<u32> = Reconnector::new("nowhere", |_| anyhow::bail!("refused"));
        assert!(r.get().is_err());
        // Within the cooldown: fast-fail, no dial storm.
        assert!(r.get().unwrap_err().to_string().contains("backing off"));
        // `connected` is eager and fails fast.
        assert!(Reconnector::<u32>::connected("nowhere", |_| anyhow::bail!("no")).is_err());
    }

    #[test]
    fn backoff_is_jittered_within_bounds() {
        for round in 0..4u32 {
            let mut r: Reconnector<u32> = Reconnector::new("nowhere", |_| anyhow::bail!("no"));
            let mut delays = Vec::new();
            for fail in 0..6u32 {
                let before = Instant::now();
                r.fail();
                let until = r.retry_after.expect("cooldown set");
                let delay = until.duration_since(before);
                let base = INITIAL_BACKOFF.saturating_mul(1u32 << fail.min(8)).min(MAX_BACKOFF);
                assert!(delay <= base + Duration::from_millis(1), "delay {delay:?} > base {base:?}");
                assert!(
                    delay >= base / 2,
                    "delay {delay:?} below jitter floor {:?} (round {round})",
                    base / 2
                );
                delays.push(delay);
            }
            // Monotone-ish growth: the 6th delay must exceed the 1st cap.
            assert!(delays[5] > INITIAL_BACKOFF, "backoff must still grow under jitter");
        }
        // Two clients of the same address walk different jitter paths.
        let mut a: Reconnector<u32> = Reconnector::new("same:1", |_| anyhow::bail!("no"));
        let mut b: Reconnector<u32> = Reconnector::new("same:1", |_| anyhow::bail!("no"));
        let mut diverged = false;
        for _ in 0..8 {
            let t = Instant::now();
            a.fail();
            b.fail();
            let da = a.retry_after.unwrap().duration_since(t);
            let db = b.retry_after.unwrap().duration_since(t);
            if da.as_micros().abs_diff(db.as_micros()) > 200 {
                diverged = true;
                break;
            }
        }
        assert!(diverged, "independent clients must not share a backoff sequence");
    }

    #[test]
    fn busy_sheds_retry_in_call_without_redialing() {
        let stats = NetStats::new();
        let dials = Arc::new(AtomicU32::new(0));
        let d2 = dials.clone();
        let mut r: Reconnector<u32> =
            Reconnector::new("nowhere", move |_| Ok(d2.fetch_add(1, Ordering::Relaxed) + 1))
                .with_stats(stats.clone());
        // Shed twice, then admitted: the call succeeds on the same
        // connection — retries must not burn the dial path.
        let mut attempts = 0u32;
        let got = r
            .with(|c| {
                attempts += 1;
                if attempts <= 2 {
                    anyhow::bail!("server busy: request shed");
                }
                Ok(*c)
            })
            .expect("busy retries within budget must succeed");
        assert_eq!(got, 1);
        assert_eq!(attempts, 3);
        assert_eq!(dials.load(Ordering::Relaxed), 1, "busy must not redial");
        assert!(r.is_connected(), "busy must not drop the connection");
        assert_eq!(stats.busy_retry_count(), 2);
        assert_eq!(stats.busy_exhausted_count(), 0);
    }

    #[test]
    fn busy_budget_exhaustion_surfaces_and_counts() {
        let stats = NetStats::new();
        let dials = Arc::new(AtomicU32::new(0));
        let d2 = dials.clone();
        let mut r: Reconnector<u32> =
            Reconnector::new("nowhere", move |_| Ok(d2.fetch_add(1, Ordering::Relaxed) + 1))
                .with_stats(stats.clone());
        let err = r
            .with(|_| -> Result<u32> { anyhow::bail!("server busy: request shed") })
            .expect_err("a persistently-shedding server must exhaust the budget");
        assert!(err.to_string().contains("still shedding"), "got: {err}");
        assert_eq!(stats.busy_retry_count(), u64::from(BUSY_RETRY_BUDGET));
        assert_eq!(stats.busy_exhausted_count(), 1);
        // The server is alive: the connection survives exhaustion and the
        // next call reuses it with a fresh budget.
        assert!(r.is_connected());
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        assert_eq!(dials.load(Ordering::Relaxed), 1);
        // A transport error still takes the drop-and-cooldown path.
        assert!(r.with(|_| -> Result<()> { anyhow::bail!("broken pipe") }).is_err());
        assert!(!r.is_connected());
        assert_eq!(stats.busy_exhausted_count(), 1, "transport errors are not busy");
    }

    #[test]
    fn transport_failures_count_inflight_loss() {
        let stats = NetStats::new();
        let dials = Arc::new(AtomicU32::new(0));
        let d2 = dials.clone();
        let mut r: Reconnector<u32> =
            Reconnector::new("nowhere", move |_| Ok(d2.fetch_add(1, Ordering::Relaxed) + 1))
                .with_stats(stats.clone());
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        assert_eq!(stats.inflight_lost_count(), 0, "success is not loss");
        // A transport error over the live connection abandons in-flight
        // state: counted.
        assert!(r.with(|_| -> Result<()> { anyhow::bail!("broken pipe") }).is_err());
        assert_eq!(stats.inflight_lost_count(), 1);
        // A refused redial has nothing in flight: not counted.
        assert!(r.get().is_err());
        assert_eq!(stats.inflight_lost_count(), 1);
        // Busy sheds keep the connection: not in-flight loss either.
        std::thread::sleep(INITIAL_BACKOFF * 3);
        assert!(r
            .with(|_| -> Result<u32> { anyhow::bail!("server busy: request shed") })
            .is_err());
        assert_eq!(stats.inflight_lost_count(), 1);
    }

    // Fault-plan sever/stall injection through the reactor is covered in
    // `tests/chaos.rs` (its own process): installing a live plan here
    // would race the other transport tests in this binary, which share
    // the process-global plan.

    #[test]
    fn nofile_limit_raise_is_best_effort() {
        let cur = raise_nofile_limit(1024);
        assert!(cur >= 256, "soft NOFILE limit suspiciously low: {cur}");
    }
}
