//! Shared TCP transport substrate for the service front-ends
//! ([`ps::net`](crate::ps::net), [`provdb::net`](crate::provdb::net), the
//! viz HTTP server) — the accept loop every server used to hand-roll, and
//! the auto-reconnect/backoff connection wrapper every long-lived client
//! used to lack.
//!
//! * [`serve_tcp`] — bind, accept on a named thread, one handler thread
//!   per connection, cooperative shutdown via [`TcpServerHandle`].
//! * [`Reconnector`] — wraps a connection `C` plus the recipe to redial
//!   it. A failed operation drops the connection; the next use redials
//!   after a capped exponential cooldown, so one peer restart never
//!   permanently strands a client (previously `NetPsClient` died on the
//!   first dropped connection while the viz `ProvSource` hand-rolled the
//!   same retry loop).
//!
//! Framing stays in [`wire`](crate::util::wire); this module is about
//! connection lifecycle.

use anyhow::{bail, Context, Result};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Handle to a running accept loop; [`stop`](Self::stop) (or drop) shuts
/// the listener down **and severs every live connection** (so stopping a
/// server actually looks like a killed process to its peers — the
/// behaviour the reconnect tests rely on). Handler threads then see EOF
/// and finish on their own.
pub struct TcpServerHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, TcpStream>>>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl TcpServerHandle {
    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Stop accepting, sever live connections, and join the accept
    /// thread. The port is free for rebinding when this returns.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        for (_, s) in self.conns.lock().expect("conn registry lock").iter() {
            let _ = s.shutdown(std::net::Shutdown::Both);
        }
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for TcpServerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Bind `addr` and serve connections: the accept loop runs on a thread
/// named `name`, and each accepted stream is handed to `handler` on its
/// own thread (thread-per-connection, matching every front-end here).
pub fn serve_tcp(
    name: &str,
    addr: &str,
    handler: impl Fn(TcpStream) + Send + Sync + 'static,
) -> Result<TcpServerHandle> {
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    listener.set_nonblocking(true)?;
    let local = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let conns: Arc<std::sync::Mutex<std::collections::HashMap<u64, TcpStream>>> =
        Arc::new(std::sync::Mutex::new(std::collections::HashMap::new()));
    let stop2 = stop.clone();
    let conns2 = conns.clone();
    let handler = Arc::new(handler);
    let join = std::thread::Builder::new()
        .name(name.to_string())
        .spawn(move || {
            let mut next_id = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let h = handler.clone();
                        // Register a clone so stop() can sever the
                        // connection; the handler wrapper deregisters on
                        // completion, keeping the registry bounded by
                        // *live* connections.
                        let id = next_id;
                        next_id += 1;
                        if let Ok(clone) = stream.try_clone() {
                            conns2.lock().expect("conn registry lock").insert(id, clone);
                        }
                        let reg = conns2.clone();
                        std::thread::spawn(move || {
                            h(stream);
                            reg.lock().expect("conn registry lock").remove(&id);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
        })?;
    Ok(TcpServerHandle { addr: local, stop, conns, join: Some(join) })
}

/// Initial reconnect cooldown after a failure; doubles per consecutive
/// failure up to [`MAX_BACKOFF`].
const INITIAL_BACKOFF: Duration = Duration::from_millis(50);
const MAX_BACKOFF: Duration = Duration::from_secs(2);

/// A connection that knows how to re-establish itself.
///
/// Operations run through [`with`](Self::with) (or the split
/// [`get`](Self::get)/[`fail`](Self::fail) pair when a caller pipelines
/// across several connections): an error drops the connection and starts
/// a capped exponential cooldown, and the next use redials. Callers
/// decide what a failed operation means (the PS router degrades the
/// affected shard's slice of a reply; the viz layer returns an empty
/// result) — the wrapper only guarantees the *connection* recovers.
pub struct Reconnector<C> {
    addr: String,
    connect: Box<dyn Fn(&str) -> Result<C> + Send>,
    conn: Option<C>,
    consecutive_failures: u32,
    retry_after: Option<Instant>,
}

impl<C> Reconnector<C> {
    /// Lazy: first use dials.
    pub fn new(addr: &str, connect: impl Fn(&str) -> Result<C> + Send + 'static) -> Self {
        Reconnector {
            addr: addr.to_string(),
            connect: Box::new(connect),
            conn: None,
            consecutive_failures: 0,
            retry_after: None,
        }
    }

    /// Eager: dial now, fail fast on a bad address.
    pub fn connected(
        addr: &str,
        connect: impl Fn(&str) -> Result<C> + Send + 'static,
    ) -> Result<Self> {
        let conn = connect(addr).with_context(|| format!("connecting to {addr}"))?;
        Ok(Self::seeded(addr, connect, conn))
    }

    /// Adopt an already-established connection (e.g. one a handshake was
    /// just read from) without redialing.
    pub fn seeded(
        addr: &str,
        connect: impl Fn(&str) -> Result<C> + Send + 'static,
        conn: C,
    ) -> Self {
        let mut r = Self::new(addr, connect);
        r.conn = Some(conn);
        r
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    pub fn is_connected(&self) -> bool {
        self.conn.is_some()
    }

    /// Borrow the live connection, redialing if necessary. Within the
    /// cooldown window after a failure this returns an error immediately
    /// instead of hammering the peer.
    pub fn get(&mut self) -> Result<&mut C> {
        if self.conn.is_none() {
            if let Some(t) = self.retry_after {
                if Instant::now() < t {
                    bail!("reconnect to {} backing off", self.addr);
                }
            }
            match (self.connect)(&self.addr) {
                Ok(c) => {
                    self.conn = Some(c);
                    self.consecutive_failures = 0;
                    self.retry_after = None;
                }
                Err(e) => {
                    self.note_failure();
                    return Err(e.context(format!("reconnecting to {}", self.addr)));
                }
            }
        }
        Ok(self.conn.as_mut().expect("connection just ensured"))
    }

    /// Drop the connection after a failed operation; the next [`get`]
    /// redials once the cooldown elapses.
    pub fn fail(&mut self) {
        self.conn = None;
        self.note_failure();
    }

    fn note_failure(&mut self) {
        let shift = self.consecutive_failures.min(8);
        let delay = INITIAL_BACKOFF.saturating_mul(1u32 << shift).min(MAX_BACKOFF);
        self.consecutive_failures = self.consecutive_failures.saturating_add(1);
        self.retry_after = Some(Instant::now() + delay);
    }

    /// Run one operation against the (re)connected peer; on error the
    /// connection is dropped so the next call redials.
    pub fn with<T>(&mut self, op: impl FnOnce(&mut C) -> Result<T>) -> Result<T> {
        let c = self.get()?;
        match op(c) {
            Ok(v) => Ok(v),
            Err(e) => {
                self.fail();
                Err(e)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::sync::atomic::AtomicU32;

    #[test]
    fn serve_tcp_round_trip_and_stop() {
        let mut srv = serve_tcp("test-echo", "127.0.0.1:0", |mut s: TcpStream| {
            let mut b = [0u8; 4];
            if s.read_exact(&mut b).is_ok() {
                let _ = s.write_all(&b);
            }
        })
        .unwrap();
        let mut c = TcpStream::connect(srv.addr()).unwrap();
        c.write_all(b"ping").unwrap();
        let mut b = [0u8; 4];
        c.read_exact(&mut b).unwrap();
        assert_eq!(&b, b"ping");
        srv.stop();
        // Stopped listener refuses new connections (eventually: the OS
        // may accept one queued conn, so just assert stop() returned).
    }

    #[test]
    fn reconnector_redials_after_failure() {
        let dials = Arc::new(AtomicU32::new(0));
        let d2 = dials.clone();
        let mut r: Reconnector<u32> = Reconnector::new("nowhere", move |_| {
            Ok(d2.fetch_add(1, Ordering::Relaxed) + 1)
        });
        assert!(!r.is_connected());
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        assert!(r.is_connected());
        // Same connection reused while healthy.
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 1);
        // A failed op drops the connection and starts the cooldown…
        assert!(r.with(|_| -> Result<()> { anyhow::bail!("boom") }).is_err());
        assert!(!r.is_connected());
        // …so an immediate retry is refused without dialing…
        assert!(r.get().is_err());
        assert_eq!(dials.load(Ordering::Relaxed), 1);
        // …and after the cooldown the next use redials.
        std::thread::sleep(INITIAL_BACKOFF * 3);
        assert_eq!(r.with(|c| Ok(*c)).unwrap(), 2);
        assert_eq!(dials.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn reconnector_connect_failures_back_off() {
        let mut r: Reconnector<u32> =
            Reconnector::new("nowhere", |_| anyhow::bail!("refused"));
        assert!(r.get().is_err());
        // Within the cooldown: fast-fail, no dial storm.
        assert!(r.get().unwrap_err().to_string().contains("backing off"));
        // `connected` is eager and fails fast.
        assert!(Reconnector::<u32>::connected("nowhere", |_| anyhow::bail!("no")).is_err());
    }
}
