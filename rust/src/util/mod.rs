//! Infrastructure substrates built in-repo (the offline registry carries no
//! serde/clap/criterion/proptest): deterministic RNG, JSON, logging, a
//! small property-testing harness, the length-prefixed wire framing
//! shared by the TCP front-ends ([`wire`]), and the shared accept-loop /
//! reconnecting-client transport layer ([`net`]).

pub mod fault;
pub mod json;
pub mod log;
pub mod net;
pub mod prop;
pub mod rng;
pub mod wire;

/// Format a byte count with binary units, e.g. `1.50 MiB`.
pub fn fmt_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{} {}", bytes, UNITS[0])
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

/// Format a duration in microseconds with a human unit.
pub fn fmt_us(us: u64) -> String {
    if us < 1_000 {
        format!("{}us", us)
    } else if us < 1_000_000 {
        format!("{:.2}ms", us as f64 / 1e3)
    } else {
        format!("{:.2}s", us as f64 / 1e6)
    }
}

/// Compute mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation of a slice (0.0 for len < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn fmt_us_units() {
        assert_eq!(fmt_us(12), "12us");
        assert_eq!(fmt_us(12_500), "12.50ms");
        assert_eq!(fmt_us(2_500_000), "2.50s");
    }

    #[test]
    fn mean_stddev_percentile() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 2.0);
        assert_eq!(percentile(&xs, 100.0), 9.0);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
