//! Deterministic pseudo-random number generation.
//!
//! Everything in the simulation (workload generation, anomaly injection,
//! property tests) must be reproducible from a seed, so we implement
//! splitmix64 (seeding) + xoshiro256++ (stream) rather than pulling in a
//! crate. Distributions cover what the trace generator needs: uniform,
//! normal (Box–Muller), lognormal, exponential and Pareto.

/// splitmix64 step — used to expand a 64-bit seed into xoshiro state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ deterministic RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Create an RNG from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Derive a child RNG (e.g. per rank) without correlating streams.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, cached_normal: None }
    }

    /// Next raw 64 bits (xoshiro256++).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → uniform double in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n` must be > 0.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + ((self.next_u64() as u128 * (hi - lo + 1) as u128) >> 64) as u64
    }

    /// Uniform float in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Lognormal: `exp(N(mu, sigma))`.
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda`.
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Pareto (heavy tail) with scale `xm` and shape `alpha`.
    #[inline]
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        xm / u.powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn usize_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.usize(7) < 7);
        }
        // All residues reachable.
        let mut seen = [false; 7];
        for _ in 0..1_000 {
            seen[r.usize(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let sd = crate::util::stddev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((sd - 1.0).abs() < 0.02, "sd {sd}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..100_000).map(|_| r.exponential(2.0)).collect();
        assert!((crate::util::mean(&xs) - 0.5).abs() < 0.02);
    }

    #[test]
    fn pareto_lower_bound() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.pareto(3.0, 2.5) >= 3.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>());
    }
}
