//! Deterministic chaos/fault-injection plane (`[chaos]` config section,
//! `rust/docs/chaos.md`).
//!
//! A seeded [`FaultPlan`] decides — reproducibly, as a pure function of
//! `(seed, site, event counter)` — when the transport and storage seams
//! misbehave: server-side read stalls, delayed replies, severed
//! connections (the reactor's `FrameDriver` consults [`read_fault`] /
//! [`reply_delay`]), torn `.provseg` tails at seal time
//! ([`torn_tail`]), and process-level kills of `ps-shard-server` /
//! `provdb-server` / `agg-node` children at chosen sync steps (the
//! supervisor in `exp/chaos.rs` executes [`FaultPlan::kills`]).
//!
//! The plan installs process-globally ([`install`]) so the hook sites
//! stay one-liners, and a relaxed-atomic fast path keeps every hook at
//! one branch when chaos is off (the production default). Child server
//! processes inherit the plan through the `CHIMBUKO_CHAOS` environment
//! variable ([`FaultPlan::spec`] / [`init_from_env`]), so one seed
//! reproduces the same fault schedule across every process of a run.

use crate::util::rng::splitmix64;
use anyhow::{bail, Context, Result};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Which child-process class a scheduled kill targets.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum KillTarget {
    /// A `ps-shard-server` child (stat shard endpoint).
    PsShard,
    /// A `provdb-server` child.
    ProvDb,
    /// An `agg-node` child (remote aggregation-tree leaf).
    AggNode,
}

impl KillTarget {
    pub fn parse(s: &str) -> Result<KillTarget> {
        match s {
            "ps" => Ok(KillTarget::PsShard),
            "provdb" => Ok(KillTarget::ProvDb),
            "agg" => Ok(KillTarget::AggNode),
            other => bail!("unknown kill target '{other}' (ps|provdb|agg)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KillTarget::PsShard => "ps",
            KillTarget::ProvDb => "provdb",
            KillTarget::AggNode => "agg",
        }
    }
}

/// One scheduled process kill: child `index` of `target`'s class dies at
/// sync step `at_step`. Written `ps:0@6` in config / env specs.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct KillSpec {
    pub target: KillTarget,
    pub index: usize,
    pub at_step: u64,
}

impl KillSpec {
    /// Parse `target:index@step`.
    pub fn parse(s: &str) -> Result<KillSpec> {
        let (head, step) =
            s.split_once('@').with_context(|| format!("kill spec '{s}' missing '@step'"))?;
        let (target, index) = head
            .split_once(':')
            .with_context(|| format!("kill spec '{s}' missing 'target:index'"))?;
        Ok(KillSpec {
            target: KillTarget::parse(target.trim())?,
            index: index.trim().parse().with_context(|| format!("kill index in '{s}'"))?,
            at_step: step.trim().parse().with_context(|| format!("kill step in '{s}'"))?,
        })
    }

    pub fn spec(&self) -> String {
        format!("{}:{}@{}", self.target.name(), self.index, self.at_step)
    }
}

/// Parse a comma-separated kill list (`ps:0@6,provdb:0@10`); empty
/// string → no kills.
pub fn parse_kills(s: &str) -> Result<Vec<KillSpec>> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(KillSpec::parse)
        .collect()
}

/// What the reactor's read path should do with the current data burst.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum ReadFault {
    /// Proceed normally.
    None,
    /// Sleep this long before parsing (a stalled server).
    Stall(Duration),
    /// Drop the connection (a mid-conversation sever).
    Sever,
}

// Per-site salts so each fault class walks an independent decision
// stream off the same seed.
const SALT_SEVER: u64 = 0x5e7e;
const SALT_STALL: u64 = 0x57a1;
const SALT_DELAY: u64 = 0xde1a;
const SALT_TORN: u64 = 0x70f2;

/// A seeded, deterministic fault schedule plus its injection counters.
///
/// Every `*_every` knob is a reciprocal rate: event `n` at a site
/// triggers when `splitmix64(seed ⊕ site ⊕ n) % every == 0`, so the
/// decision depends only on the seed and the site's event ordinal —
/// re-running with the same seed replays the same schedule. `0`
/// disables that fault class.
#[derive(Debug, Default)]
pub struct FaultPlan {
    pub seed: u64,
    /// Sever an incoming connection's read burst every ~N bursts.
    pub sever_every: u64,
    /// Stall the read path every ~N bursts, for `stall_ms`.
    pub stall_every: u64,
    pub stall_ms: u64,
    /// Delay a reply every ~N admitted frames, by `delay_ms`.
    pub delay_every: u64,
    pub delay_ms: u64,
    /// Tear the tail off every ~Nth sealed `.provseg` segment, leaving
    /// it `torn_tail_bytes` short (recovery must salvage + sideline).
    pub torn_every: u64,
    pub torn_tail_bytes: u64,
    /// Scheduled child-process kills (the supervisor executes these).
    pub kills: Vec<KillSpec>,
    // Injection counters: how often each hook fired (relaxed; read by
    // the chaos harness for its bounded-loss accounting).
    bursts: AtomicU64,
    frames: AtomicU64,
    seals: AtomicU64,
    severed: AtomicU64,
    stalled: AtomicU64,
    delayed: AtomicU64,
    torn: AtomicU64,
}

impl FaultPlan {
    /// A plan that schedules kills but injects no transport faults.
    pub fn kills_only(seed: u64, kills: Vec<KillSpec>) -> FaultPlan {
        FaultPlan { seed, kills, ..FaultPlan::default() }
    }

    /// Whether any fault class is live (a default plan is inert).
    pub fn any_faults(&self) -> bool {
        self.sever_every > 0
            || self.stall_every > 0
            || self.delay_every > 0
            || self.torn_every > 0
            || !self.kills.is_empty()
    }

    /// Deterministic trigger decision for event `n` at `salt`'s site.
    fn hit(&self, salt: u64, n: u64, every: u64) -> bool {
        if every == 0 {
            return false;
        }
        let mut s = self.seed ^ salt.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ n;
        splitmix64(&mut s) % every == 0
    }

    /// Consult the plan for one server-side read burst.
    pub fn read_fault(&self) -> ReadFault {
        let n = self.bursts.fetch_add(1, Ordering::Relaxed);
        if self.hit(SALT_SEVER, n, self.sever_every) {
            self.severed.fetch_add(1, Ordering::Relaxed);
            return ReadFault::Sever;
        }
        if self.hit(SALT_STALL, n, self.stall_every) {
            self.stalled.fetch_add(1, Ordering::Relaxed);
            return ReadFault::Stall(Duration::from_millis(self.stall_ms));
        }
        ReadFault::None
    }

    /// Consult the plan before one reply dispatch.
    pub fn reply_delay(&self) -> Option<Duration> {
        let n = self.frames.fetch_add(1, Ordering::Relaxed);
        if self.hit(SALT_DELAY, n, self.delay_every) {
            self.delayed.fetch_add(1, Ordering::Relaxed);
            return Some(Duration::from_millis(self.delay_ms));
        }
        None
    }

    /// Bytes to tear off the segment being sealed (0 = seal cleanly).
    pub fn torn_tail(&self) -> u64 {
        let n = self.seals.fetch_add(1, Ordering::Relaxed);
        if self.hit(SALT_TORN, n, self.torn_every) {
            self.torn.fetch_add(1, Ordering::Relaxed);
            return self.torn_tail_bytes;
        }
        0
    }

    pub fn severed_count(&self) -> u64 {
        self.severed.load(Ordering::Relaxed)
    }

    pub fn stalled_count(&self) -> u64 {
        self.stalled.load(Ordering::Relaxed)
    }

    pub fn delayed_count(&self) -> u64 {
        self.delayed.load(Ordering::Relaxed)
    }

    pub fn torn_count(&self) -> u64 {
        self.torn.load(Ordering::Relaxed)
    }

    /// Serialize to the `CHIMBUKO_CHAOS` hand-off spec (`k=v;k=v;…`),
    /// so child server processes replay the same schedule.
    pub fn spec(&self) -> String {
        let mut s = format!(
            "seed={};sever_every={};stall_every={};stall_ms={};delay_every={};\
             delay_ms={};torn_every={};torn_tail_bytes={}",
            self.seed,
            self.sever_every,
            self.stall_every,
            self.stall_ms,
            self.delay_every,
            self.delay_ms,
            self.torn_every,
            self.torn_tail_bytes,
        );
        if !self.kills.is_empty() {
            let kills: Vec<String> = self.kills.iter().map(KillSpec::spec).collect();
            s.push_str(";kills=");
            s.push_str(&kills.join(","));
        }
        s
    }

    /// Parse a [`spec`](Self::spec) string back into a plan.
    pub fn from_spec(text: &str) -> Result<FaultPlan> {
        let mut p = FaultPlan::default();
        for pair in text.split(';').map(str::trim).filter(|s| !s.is_empty()) {
            let (k, v) =
                pair.split_once('=').with_context(|| format!("chaos spec pair '{pair}'"))?;
            let v = v.trim();
            match k.trim() {
                "seed" => p.seed = v.parse()?,
                "sever_every" => p.sever_every = v.parse()?,
                "stall_every" => p.stall_every = v.parse()?,
                "stall_ms" => p.stall_ms = v.parse()?,
                "delay_every" => p.delay_every = v.parse()?,
                "delay_ms" => p.delay_ms = v.parse()?,
                "torn_every" => p.torn_every = v.parse()?,
                "torn_tail_bytes" => p.torn_tail_bytes = v.parse()?,
                "kills" => p.kills = parse_kills(v)?,
                other => bail!("unknown chaos spec key '{other}'"),
            }
        }
        Ok(p)
    }
}

// ---------------------------------------------------------------------------
// Process-global installation: the hook sites in `util/net.rs` and
// `provdb/store.rs` cannot thread a plan handle through every
// constructor, so the active plan lives here. `ENABLED` is the fast
// path — when false (the default), every hook is one relaxed load.

static ENABLED: AtomicBool = AtomicBool::new(false);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

/// Install `plan` as the process's active fault plan.
pub fn install(plan: Arc<FaultPlan>) {
    *PLAN.lock().expect("fault plan lock") = Some(plan);
    ENABLED.store(true, Ordering::Release);
}

/// Deactivate fault injection (hooks return to their no-op fast path).
pub fn clear() {
    ENABLED.store(false, Ordering::Release);
    *PLAN.lock().expect("fault plan lock") = None;
}

/// Whether a plan is installed.
pub fn active() -> bool {
    ENABLED.load(Ordering::Acquire)
}

/// The installed plan, if any.
pub fn current() -> Option<Arc<FaultPlan>> {
    if !active() {
        return None;
    }
    PLAN.lock().expect("fault plan lock").clone()
}

/// Hook: one server-side read burst ([`FrameDriver`] read path).
pub fn read_fault() -> ReadFault {
    match current() {
        Some(p) => p.read_fault(),
        None => ReadFault::None,
    }
}

/// Hook: delay before dispatching one admitted frame to its handler.
pub fn reply_delay() -> Option<Duration> {
    current().and_then(|p| p.reply_delay())
}

/// Hook: bytes to tear off the `.provseg` segment being sealed.
pub fn torn_tail() -> u64 {
    current().map(|p| p.torn_tail()).unwrap_or(0)
}

/// Adopt a plan from the `CHIMBUKO_CHAOS` environment variable (how the
/// chaos harness's child server processes inherit the schedule). A
/// malformed spec is a hard error: a chaos run with a silently-ignored
/// plan would assert against faults that never fired.
pub fn init_from_env() -> Result<()> {
    let Ok(spec) = std::env::var("CHIMBUKO_CHAOS") else {
        return Ok(());
    };
    if spec.trim().is_empty() {
        return Ok(());
    }
    let plan = FaultPlan::from_spec(&spec).context("parsing CHIMBUKO_CHAOS")?;
    crate::log_info!("fault", "chaos plan from env: {}", plan.spec());
    install(Arc::new(plan));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            sever_every: 16,
            stall_every: 8,
            stall_ms: 5,
            delay_every: 4,
            delay_ms: 2,
            torn_every: 2,
            torn_tail_bytes: 5,
            ..FaultPlan::default()
        }
    }

    #[test]
    fn same_seed_replays_the_same_schedule() {
        let a = plan(7);
        let b = plan(7);
        for _ in 0..512 {
            assert_eq!(a.read_fault(), b.read_fault());
            assert_eq!(a.reply_delay(), b.reply_delay());
            assert_eq!(a.torn_tail(), b.torn_tail());
        }
        assert!(a.severed_count() > 0, "sever rate 1/16 over 512 bursts must fire");
        assert_eq!(a.severed_count(), b.severed_count());
        assert_eq!(a.stalled_count(), b.stalled_count());
        assert_eq!(a.delayed_count(), b.delayed_count());
        assert_eq!(a.torn_count(), b.torn_count());
    }

    #[test]
    fn different_seeds_diverge() {
        let a = plan(1);
        let b = plan(2);
        let same = (0..256).filter(|_| a.read_fault() == b.read_fault()).count();
        assert!(same < 256, "seeds must alter the schedule");
    }

    #[test]
    fn rates_are_roughly_reciprocal() {
        let p = plan(42);
        let torn = (0..1000).filter(|_| p.torn_tail() > 0).count();
        // 1/2 rate over 1000 seals: binomial bounds, generous.
        assert!((350..=650).contains(&torn), "torn {torn}/1000 at rate 1/2");
    }

    #[test]
    fn zero_knobs_are_inert() {
        let p = FaultPlan { seed: 9, ..FaultPlan::default() };
        assert!(!p.any_faults());
        for _ in 0..64 {
            assert_eq!(p.read_fault(), ReadFault::None);
            assert_eq!(p.reply_delay(), None);
            assert_eq!(p.torn_tail(), 0);
        }
    }

    #[test]
    fn kill_specs_parse_and_roundtrip() {
        let kills = parse_kills("ps:0@6, provdb:1@10, agg:2@3").unwrap();
        assert_eq!(
            kills,
            vec![
                KillSpec { target: KillTarget::PsShard, index: 0, at_step: 6 },
                KillSpec { target: KillTarget::ProvDb, index: 1, at_step: 10 },
                KillSpec { target: KillTarget::AggNode, index: 2, at_step: 3 },
            ]
        );
        assert_eq!(kills[0].spec(), "ps:0@6");
        assert!(parse_kills("").unwrap().is_empty());
        assert!(parse_kills("ps:0").is_err());
        assert!(parse_kills("disk:0@4").is_err());
        assert!(parse_kills("ps@4").is_err());
    }

    #[test]
    fn spec_roundtrips_through_text() {
        let mut p = plan(99);
        p.kills = parse_kills("ps:0@6,provdb:0@10").unwrap();
        let q = FaultPlan::from_spec(&p.spec()).unwrap();
        assert_eq!(q.seed, 99);
        assert_eq!(q.sever_every, 16);
        assert_eq!(q.torn_tail_bytes, 5);
        assert_eq!(q.kills, p.kills);
        // And the schedules match, since decisions are (seed, n)-pure.
        for _ in 0..128 {
            assert_eq!(p.read_fault(), q.read_fault());
        }
        assert!(FaultPlan::from_spec("bogus=1").is_err());
        assert!(FaultPlan::from_spec("seed").is_err());
    }

    #[test]
    fn global_install_gates_the_hooks() {
        // Keep the installed plan inert (all rates 0) so concurrently
        // running transport tests in this binary are unaffected.
        assert!(!active());
        assert_eq!(read_fault(), ReadFault::None);
        assert_eq!(torn_tail(), 0);
        install(Arc::new(FaultPlan { seed: 3, ..FaultPlan::default() }));
        assert!(active());
        assert_eq!(read_fault(), ReadFault::None, "inert plan: hooks still no-op");
        clear();
        assert!(!active());
        assert!(current().is_none());
    }
}
