//! Mini property-testing harness (no `proptest` offline).
//!
//! A property is a closure from a seeded [`Rng`](super::rng::Rng) to a
//! `Result<(), String>`; the harness runs it for `cases` seeds and, on
//! failure, retries the failing seed with progressively smaller `size`
//! hints to report the smallest reproduction it can find. Generators take
//! `(rng, size)` so shrinking works for free on sized inputs.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: u32,
    /// Base seed; each case uses `seed + case_index`.
    pub seed: u64,
    /// Maximum size hint passed to the property.
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, seed: 0xC41B_0001, max_size: 256 }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` seeds; panic with the seed and the
/// smallest failing size on failure.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64);
        // Sizes sweep small → large so early cases are trivially debuggable.
        let size = 1 + (case as usize * cfg.max_size) / cfg.cases.max(1) as usize;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: re-run the same seed at smaller sizes.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                match prop(&mut rng, s) {
                    Err(m) => {
                        smallest = (s, m);
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed: seed={seed} size={} (first failing size {size}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Convenience: run with default config.
pub fn check_default<F>(name: &str, prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    check(name, Config::default(), prop)
}

/// Generate a vector of `len` items using `gen`.
pub fn vec_of<T>(rng: &mut Rng, len: usize, mut gen: impl FnMut(&mut Rng) -> T) -> Vec<T> {
    (0..len).map(|_| gen(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_default("reverse-involution", |rng, size| {
            let v = vec_of(rng, size, |r| r.next_u64());
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            if v == w {
                Ok(())
            } else {
                Err("reverse twice changed vector".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check(
            "always-fails",
            Config { cases: 3, ..Config::default() },
            |_rng, _size| Err("nope".into()),
        );
    }

    #[test]
    fn shrinking_finds_small_size() {
        let result = std::panic::catch_unwind(|| {
            check(
                "fails-above-4",
                Config { cases: 64, seed: 9, max_size: 100 },
                |_rng, size| {
                    if size > 4 {
                        Err(format!("size {size} too big"))
                    } else {
                        Ok(())
                    }
                },
            )
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrink loop halves until the property passes; the reported
        // failing size must be ≤ 2× the true threshold.
        assert!(msg.contains("size=5") || msg.contains("size=6") || msg.contains("size=7") || msg.contains("size=8"),
            "unexpected shrink result: {msg}");
    }
}
