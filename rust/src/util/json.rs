//! Minimal JSON value model, writer and recursive-descent parser.
//!
//! The offline registry has no `serde`/`serde_json`, and Chimbuko's reduced
//! output format is JSON (the paper dumps anomalies + provenance as JSON
//! files), so we implement the subset we need: full RFC 8259 syntax, object
//! key ordering preserved (Vec-backed), f64 numbers, `\uXXXX` escapes
//! (including surrogate pairs).

use std::fmt;

/// A JSON value. Objects preserve insertion order — provenance records are
/// diffed across runs, so stable field order keeps diffs meaningful.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object constructor from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array constructor.
    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    /// String constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Number constructor.
    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Insert/replace a key in an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(pairs) => {
                if let Some(slot) = pairs.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string(&self) -> String {
        let mut out = String::with_capacity(64);
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::with_capacity(128);
        self.write_pretty(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push_str("[\n");
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        // JSON has no NaN/Inf; provenance must stay parseable.
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{}", n));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document (must consume the full input up to whitespace).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

/// JSON parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            pairs.push((key, value));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(pairs)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{08}'),
                    Some(b'f') => s.push('\u{0C}'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // Surrogate pair.
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                        } else {
                            hi
                        };
                        s.push(
                            char::from_u32(cp)
                                .ok_or_else(|| self.err("invalid codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences from the source.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(b);
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(b: u8) -> usize {
    if b >= 0xF0 {
        4
    } else if b >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(j: &Json) {
        let s = j.to_string();
        assert_eq!(&parse(&s).unwrap(), j, "roundtrip failed for {s}");
    }

    #[test]
    fn scalars() {
        roundtrip(&Json::Null);
        roundtrip(&Json::Bool(true));
        roundtrip(&Json::Bool(false));
        roundtrip(&Json::Num(0.0));
        roundtrip(&Json::Num(-17.0));
        roundtrip(&Json::Num(3.25));
        roundtrip(&Json::Str("hello".into()));
    }

    #[test]
    fn escapes_roundtrip() {
        roundtrip(&Json::Str("a\"b\\c\nd\te\u{08}\u{0C}\r\u{1}".into()));
        roundtrip(&Json::Str("unicode: héllo – 日本語 🚀".into()));
    }

    #[test]
    fn surrogate_pair_parse() {
        assert_eq!(parse(r#""🚀""#).unwrap(), Json::Str("🚀".into()));
    }

    #[test]
    fn nested_structures() {
        roundtrip(&Json::obj(vec![
            ("rank", Json::num(3)),
            ("anoms", Json::arr(vec![Json::num(1), Json::num(2)])),
            ("meta", Json::obj(vec![("app", Json::str("nwchem"))])),
            ("empty_a", Json::Arr(vec![])),
            ("empty_o", Json::Obj(vec![])),
        ]));
    }

    #[test]
    fn numbers_parse_forms() {
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("-2.5E-2").unwrap(), Json::Num(-0.025));
        assert_eq!(parse("0").unwrap(), Json::Num(0.0));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("\"abc").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{\"a\":1} extra").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn object_get_set_preserves_order() {
        let mut o = Json::obj(vec![("a", Json::num(1)), ("b", Json::num(2))]);
        o.set("c", Json::num(3));
        o.set("a", Json::num(9));
        assert_eq!(o.get("a").unwrap().as_f64(), Some(9.0));
        assert_eq!(o.to_string(), r#"{"a":9,"b":2,"c":3}"#);
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::num(148.0).to_string(), "148");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }

    #[test]
    fn nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn pretty_output_parses_back() {
        let j = Json::obj(vec![
            ("xs", Json::arr(vec![Json::num(1), Json::num(2)])),
            ("s", Json::str("x")),
        ]);
        assert_eq!(parse(&j.to_pretty()).unwrap(), j);
    }
}
