//! Tiny leveled logger (stderr), controlled by `CHIMBUKO_LOG`
//! (`error|warn|info|debug|trace`, default `info`) or the `-v` / `-vv`
//! CLI flags (debug / trace). Thread-safe; used by the long-running
//! components (PS, viz server, coordinator).
//!
//! Two chaos-plane additions (`rust/docs/chaos.md`):
//! * `CHIMBUKO_LOG_FILE` tees every emitted record to a file, so CI can
//!   upload the full `-vv` execution trace even when stderr is truncated.
//! * [`trace_step`] emits fixed-column execution-trace records
//!   (`step│actor│event│detail`, strict column budget) at `Trace` level —
//!   the format chaos failures are diagnosed from.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_from_env() -> u8 {
    let lvl = match std::env::var("CHIMBUKO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True if `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == u8::MAX {
        max = init_from_env();
    }
    (level as u8) <= max
}

/// Override the level programmatically (tests, `--quiet`, `-v`/`-vv`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

// Tee sink for `CHIMBUKO_LOG_FILE`: 0 = unprobed, 1 = off, 2 = on.
static TEE_STATE: AtomicU8 = AtomicU8::new(0);
static TEE: Mutex<Option<std::fs::File>> = Mutex::new(None);

fn tee_line(line: &str) {
    let state = TEE_STATE.load(Ordering::Relaxed);
    if state == 1 {
        return;
    }
    if state == 0 {
        let opened = std::env::var("CHIMBUKO_LOG_FILE").ok().filter(|p| !p.is_empty()).and_then(
            |p| std::fs::File::options().create(true).append(true).open(p).ok(),
        );
        let on = opened.is_some();
        *TEE.lock().expect("log tee lock") = opened;
        TEE_STATE.store(if on { 2 } else { 1 }, Ordering::Relaxed);
        if !on {
            return;
        }
    }
    if let Some(f) = TEE.lock().expect("log tee lock").as_mut() {
        let _ = f.write_all(line.as_bytes());
    }
}

/// Emit a record; prefer the `log_*` macros.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let line = format!(
        "[{:>10}.{:03} {tag} {target}] {msg}\n",
        now.as_secs(),
        now.subsec_millis()
    );
    let _ = std::io::stderr().write_all(line.as_bytes());
    tee_line(&line);
}

// Execution-trace column budget: the four fields of a [`trace_step`]
// record are clipped to these widths so a `-vv` log stays one aligned,
// greppable table (≈100 columns with the timestamp prefix) no matter
// what a detail string contains.
const TRACE_ACTOR_W: usize = 12;
const TRACE_EVENT_W: usize = 16;
const TRACE_DETAIL_W: usize = 48;

fn clip(s: &str, w: usize) -> String {
    if s.len() <= w {
        return s.to_string();
    }
    let mut cut = w.saturating_sub(1);
    while cut > 0 && !s.is_char_boundary(cut) {
        cut -= 1;
    }
    format!("{}…", &s[..cut])
}

/// Emit one fixed-column execution-trace record at `Trace` level:
/// `step│actor        │event           │detail`. The chaos harness and
/// the supervisor stamp every state transition through here, so a `-vv`
/// run reads as a single chronological table.
pub fn trace_step(target: &str, step: u64, actor: &str, event: &str, detail: &str) {
    if !enabled(Level::Trace) {
        return;
    }
    emit(
        Level::Trace,
        target,
        format_args!(
            "{step:>6}│{:<aw$}│{:<ew$}│{}",
            clip(actor, TRACE_ACTOR_W),
            clip(event, TRACE_EVENT_W),
            clip(detail, TRACE_DETAIL_W),
            aw = TRACE_ACTOR_W,
            ew = TRACE_EVENT_W,
        ),
    );
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Trace));
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info);
    }

    #[test]
    fn trace_columns_are_clipped() {
        assert_eq!(clip("short", 12), "short");
        let long = "a-very-long-actor-name-over-budget";
        let c = clip(long, 12);
        assert!(c.chars().count() <= 12);
        assert!(c.ends_with('…'));
        // Multi-byte boundaries are respected (no panic, no torn char).
        let uni = "αβγδεζηθικλμν";
        let cu = clip(uni, 6);
        assert!(cu.chars().count() <= 6);
    }
}
