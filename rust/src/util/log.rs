//! Tiny leveled logger (stderr), controlled by `CHIMBUKO_LOG`
//! (`error|warn|info|debug|trace`, default `info`). Thread-safe; used by
//! the long-running components (PS, viz server, coordinator).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);

fn init_from_env() -> u8 {
    let lvl = match std::env::var("CHIMBUKO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    MAX_LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True if `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    let mut max = MAX_LEVEL.load(Ordering::Relaxed);
    if max == u8::MAX {
        max = init_from_env();
    }
    (level as u8) <= max
}

/// Override the level programmatically (tests, `--quiet`).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Emit a record; prefer the `log_*` macros.
pub fn emit(level: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let now = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    let line = format!(
        "[{:>10}.{:03} {tag} {target}] {msg}\n",
        now.as_secs(),
        now.subsec_millis()
    );
    let _ = std::io::stderr().write_all(line.as_bytes());
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}
