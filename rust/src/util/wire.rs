//! Length-prefixed binary framing shared by the TCP front-ends
//! ([`ps::net`](crate::ps::net) and [`provdb::net`](crate::provdb::net)).
//!
//! Every frame is `u32 len (LE), u32 stream (LE), len bytes of payload`;
//! payloads start with a one-byte request kind and are decoded with
//! [`Cursor`]. Strings travel as `u32 len, len UTF-8 bytes` ([`put_str`] /
//! [`Cursor::str`]).
//!
//! The **stream id** multiplexes independent logical request/reply
//! streams over one socket (a driver's conn-pool slots share a socket;
//! the server echoes the request's stream id on its reply). Simple
//! single-stream peers use [`write_msg`] / [`read_msg`], which pin
//! stream 0. Stream ids with [`CTRL_BIT`] set are transport control
//! frames addressed to `stream & !CTRL_BIT`; the only opcode today is
//! [`CTRL_BUSY`] — the server shed the request under overload and the
//! client should back off (the `Reconnector` cooldown) and retry.

use anyhow::{bail, Context, Result};
use std::io::{Read, Write};

/// Upper bound on a single message; a peer announcing more is treated as
/// malformed (the wire is a trust boundary).
pub const MAX_MSG: usize = 64 << 20;

/// Bytes of frame header preceding the payload (`u32 len, u32 stream`).
pub const FRAME_HEADER: usize = 8;

/// Stream-id bit marking a transport control frame. Control frames are
/// emitted only by servers; a client sending one is malformed.
pub const CTRL_BIT: u32 = 0x8000_0000;

/// Control opcode (first payload byte): the server's bounded ingest
/// queues are full and this request was shed without being processed.
pub const CTRL_BUSY: u8 = 1;

/// Write one frame on `stream` and flush.
pub fn write_frame<W: Write>(w: &mut W, stream: u32, payload: &[u8]) -> Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(&stream.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame; `None` on clean EOF before the header.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<(u32, Vec<u8>)>> {
    let mut len = [0u8; 4];
    match r.read_exact(&mut len) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e.into()),
    }
    let n = u32::from_le_bytes(len) as usize;
    if n > MAX_MSG {
        bail!("message too large: {n}");
    }
    let mut stream = [0u8; 4];
    r.read_exact(&mut stream).context("frame stream id")?;
    let mut buf = vec![0u8; n];
    r.read_exact(&mut buf).context("message body")?;
    Ok(Some((u32::from_le_bytes(stream), buf)))
}

/// Write one message on stream 0 and flush (single-stream peers).
pub fn write_msg<W: Write>(w: &mut W, payload: &[u8]) -> Result<()> {
    write_frame(w, 0, payload)
}

/// Read one message; `None` on clean EOF before the header. Control
/// frames are handled here: `Busy` becomes an error (the request was
/// shed — callers route it through their `Reconnector` failure path),
/// unknown control opcodes are skipped.
pub fn read_msg<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    loop {
        match read_frame(r)? {
            None => return Ok(None),
            Some((stream, payload)) => {
                if stream & CTRL_BIT != 0 {
                    if payload.first() == Some(&CTRL_BUSY) {
                        bail!("server busy: request shed");
                    }
                    continue;
                }
                return Ok(Some(payload));
            }
        }
    }
}

/// Append a length-prefixed UTF-8 string to a message under construction.
pub fn put_str(buf: &mut Vec<u8>, s: &str) {
    buf.extend_from_slice(&(s.len() as u32).to_le_bytes());
    buf.extend_from_slice(s.as_bytes());
}

/// Bounds-checked little-endian payload reader.
pub struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    pub fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    pub fn take<const N: usize>(&mut self) -> Result<[u8; N]> {
        if self.pos + N > self.buf.len() {
            bail!("truncated message");
        }
        let mut b = [0u8; N];
        b.copy_from_slice(&self.buf[self.pos..self.pos + N]);
        self.pos += N;
        Ok(b)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take::<1>()?[0])
    }

    pub fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take()?))
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take()?))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take()?))
    }

    /// Unread bytes left in the message.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// The unread remainder, without consuming it (callers that parse
    /// self-delimiting sub-records peek, measure, then [`Cursor::take_slice`]).
    pub fn peek(&self) -> &'a [u8] {
        &self.buf[self.pos..]
    }

    /// Consume `n` bytes, borrowed from the underlying message (no copy).
    pub fn take_slice(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!("truncated message");
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read a length-prefixed byte string (the raw, zero-copy form of
    /// [`Cursor::str`]).
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        if n > self.remaining() {
            bail!("truncated string");
        }
        self.take_slice(n)
    }

    /// Read a length-prefixed UTF-8 string (see [`put_str`]).
    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes()?;
        Ok(std::str::from_utf8(b)
            .context("non-UTF-8 string on the wire")?
            .to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_roundtrip_over_a_buffer() {
        let mut buf = Vec::new();
        write_msg(&mut buf, b"hello").unwrap();
        write_msg(&mut buf, b"").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_msg(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_msg(&mut r).unwrap().unwrap(), b"");
        assert!(read_msg(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn frames_carry_stream_ids() {
        let mut buf = Vec::new();
        write_frame(&mut buf, 3, b"abc").unwrap();
        write_frame(&mut buf, 0, b"z").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), (3, b"abc".to_vec()));
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), (0, b"z".to_vec()));
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF");
    }

    #[test]
    fn busy_control_frame_errors_read_msg() {
        let mut buf = Vec::new();
        write_frame(&mut buf, CTRL_BIT, &[CTRL_BUSY]).unwrap();
        let mut r = buf.as_slice();
        let err = read_msg(&mut r).unwrap_err();
        assert!(err.to_string().contains("busy"), "got: {err}");
        // Unknown control opcodes are skipped, not fatal.
        let mut buf = Vec::new();
        write_frame(&mut buf, CTRL_BIT | 7, &[0xEE]).unwrap();
        write_msg(&mut buf, b"after").unwrap();
        let mut r = buf.as_slice();
        assert_eq!(read_msg(&mut r).unwrap().unwrap(), b"after");
    }

    #[test]
    fn cursor_reads_scalars_and_strings() {
        let mut msg = vec![7u8];
        msg.extend_from_slice(&42u32.to_le_bytes());
        msg.extend_from_slice(&9u64.to_le_bytes());
        msg.extend_from_slice(&1.5f64.to_le_bytes());
        put_str(&mut msg, "chimbuko");
        let mut c = Cursor::new(&msg);
        assert_eq!(c.u8().unwrap(), 7);
        assert_eq!(c.u32().unwrap(), 42);
        assert_eq!(c.u64().unwrap(), 9);
        assert_eq!(c.f64().unwrap(), 1.5);
        assert_eq!(c.str().unwrap(), "chimbuko");
        assert!(c.u8().is_err(), "exhausted cursor must refuse");
    }

    #[test]
    fn cursor_slice_and_peek_reads() {
        let mut msg = Vec::new();
        msg.extend_from_slice(&7u16.to_le_bytes());
        put_str(&mut msg, "abc");
        msg.extend_from_slice(b"xyz");
        let mut c = Cursor::new(&msg);
        assert_eq!(c.u16().unwrap(), 7);
        assert_eq!(c.bytes().unwrap(), b"abc");
        assert_eq!(c.remaining(), 3);
        assert_eq!(c.peek(), b"xyz");
        assert_eq!(c.take_slice(2).unwrap(), b"xy");
        assert!(c.take_slice(2).is_err(), "over-read must refuse");
        assert_eq!(c.take_slice(1).unwrap(), b"z");
        assert_eq!(c.remaining(), 0);
        assert!(c.peek().is_empty());
    }

    #[test]
    fn truncated_inputs_rejected() {
        let mut msg = Vec::new();
        put_str(&mut msg, "abc");
        msg.truncate(msg.len() - 1);
        let mut c = Cursor::new(&msg);
        assert!(c.str().is_err());
        // Oversized length prefix refused before allocation.
        let mut r: &[u8] = &(u32::MAX).to_le_bytes()[..];
        assert!(read_msg(&mut r).is_err());
    }
}
