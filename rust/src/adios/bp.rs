//! BP file engine — dump-to-disk trace output (the "NWChem + TAU" baseline
//! in Figs 8–9). Wraps the [`binfmt`](crate::trace::binfmt) codec with a
//! buffered file writer and byte accounting; also supports a counting-only
//! mode so the Fig 9 size sweep can model multi-TB runs without writing
//! them.

use crate::trace::binfmt;
use crate::trace::StepFrame;
use anyhow::{Context, Result};
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

enum Sink {
    File(BufWriter<File>),
    /// Count bytes only — used for large-scale size sweeps.
    Counting,
}

/// BP-like trace file writer with byte accounting.
pub struct BpWriter {
    sink: Sink,
    bytes: u64,
    frames: u64,
    events: u64,
}

impl BpWriter {
    /// Create a real file-backed writer.
    pub fn create(path: &Path) -> Result<BpWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let f = File::create(path)
            .with_context(|| format!("creating bp file {}", path.display()))?;
        Ok(BpWriter { sink: Sink::File(BufWriter::new(f)), bytes: 0, frames: 0, events: 0 })
    }

    /// Create a byte-counting writer (no I/O).
    pub fn counting() -> BpWriter {
        BpWriter { sink: Sink::Counting, bytes: 0, frames: 0, events: 0 }
    }

    /// Append one step frame.
    pub fn put_step(&mut self, frame: &StepFrame) -> Result<()> {
        let n = match &mut self.sink {
            Sink::File(w) => binfmt::write_frame(w, frame)?,
            Sink::Counting => binfmt::frame_encoded_size(frame),
        };
        self.bytes += n;
        self.frames += 1;
        self.events += frame.events.len() as u64;
        Ok(())
    }

    /// Flush file buffers (no-op when counting).
    pub fn flush(&mut self) -> Result<()> {
        if let Sink::File(w) = &mut self.sink {
            w.flush().context("flushing bp file")?;
        }
        Ok(())
    }

    pub fn bytes_written(&self) -> u64 {
        self.bytes
    }

    pub fn frames_written(&self) -> u64 {
        self.frames
    }

    pub fn events_written(&self) -> u64 {
        self.events
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::binfmt::read_all;
    use crate::trace::gen::{toy_grammar, RankTracer};
    use crate::util::rng::Rng;

    fn frames(n: usize) -> Vec<StepFrame> {
        let (g, _) = toy_grammar();
        let mut t = RankTracer::new(g, 0, 0, 2, false, Rng::new(1));
        (0..n).map(|_| t.step()).collect()
    }

    #[test]
    fn file_writer_roundtrips() {
        let dir = std::env::temp_dir().join(format!("chimbuko-bp-{}", std::process::id()));
        let path = dir.join("trace.bp");
        let fs = frames(5);
        let mut w = BpWriter::create(&path).unwrap();
        for f in &fs {
            w.put_step(f).unwrap();
        }
        w.flush().unwrap();
        assert_eq!(w.frames_written(), 5);
        let on_disk = std::fs::metadata(&path).unwrap().len();
        assert_eq!(on_disk, w.bytes_written());
        let back = read_all(&mut std::fs::File::open(&path).unwrap()).unwrap();
        assert_eq!(back.len(), 5);
        assert_eq!(back[2].events, fs[2].events);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn counting_writer_matches_file_writer() {
        let fs = frames(4);
        let dir = std::env::temp_dir().join(format!("chimbuko-bpc-{}", std::process::id()));
        let mut fw = BpWriter::create(&dir.join("t.bp")).unwrap();
        let mut cw = BpWriter::counting();
        for f in &fs {
            fw.put_step(f).unwrap();
            cw.put_step(f).unwrap();
        }
        fw.flush().unwrap();
        assert_eq!(fw.bytes_written(), cw.bytes_written());
        assert_eq!(fw.events_written(), cw.events_written());
        std::fs::remove_dir_all(&dir).ok();
    }
}
