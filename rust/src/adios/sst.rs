//! SST-like in-process step stream.
//!
//! Semantics copied from ADIOS2's Sustainable Staging Transport as Chimbuko
//! uses it (§II-C): the producer (TAU plugin ≙ [`RankTracer`]) publishes
//! one *step* at a time; the consumer (on-node AD) blocks on `begin_step`
//! until a step is available; a bounded queue applies backpressure to the
//! producer so a slow analysis cannot buffer unbounded trace data (the
//! paper's "minimal memory overhead on the senders' side").
//!
//! Implementation: `Mutex<VecDeque>` + two `Condvar`s; `close()` lets the
//! reader drain remaining steps then observe EndOfStream.

use crate::trace::StepFrame;
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Result of `begin_step` — mirrors adios2::StepStatus.
#[derive(Debug, PartialEq)]
pub enum StepStatus {
    /// A step is available (payload attached).
    Ok(Box<StepFrame>),
    /// Producer closed and the queue is drained.
    EndOfStream,
    /// `try_begin_step` found nothing within the timeout.
    NotReady,
}

struct Shared {
    queue: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State {
    frames: VecDeque<StepFrame>,
    closed: bool,
    /// Steps the writer had to wait on (backpressure events) — a metric
    /// the overhead experiments report.
    writer_waits: u64,
}

/// Producer handle.
pub struct SstWriter {
    shared: Arc<Shared>,
}

/// Consumer handle.
pub struct SstReader {
    shared: Arc<Shared>,
}

/// Create a bounded step stream of depth `capacity`.
pub fn sst_channel(capacity: usize) -> (SstWriter, SstReader) {
    assert!(capacity > 0, "sst capacity must be > 0");
    let shared = Arc::new(Shared {
        queue: Mutex::new(State { frames: VecDeque::new(), closed: false, writer_waits: 0 }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (SstWriter { shared: shared.clone() }, SstReader { shared })
}

impl SstWriter {
    /// Publish one step; blocks while the queue is full (backpressure).
    pub fn put_step(&self, frame: StepFrame) {
        let mut st = self.shared.queue.lock().unwrap();
        if st.frames.len() >= self.shared.capacity {
            st.writer_waits += 1;
            while st.frames.len() >= self.shared.capacity && !st.closed {
                st = self.shared.not_full.wait(st).unwrap();
            }
        }
        if st.closed {
            return; // reader went away; drop silently like SST on close
        }
        st.frames.push_back(frame);
        drop(st);
        self.shared.not_empty.notify_one();
    }

    /// Number of times the writer blocked on a full queue.
    pub fn writer_waits(&self) -> u64 {
        self.shared.queue.lock().unwrap().writer_waits
    }

    /// Close the stream; the reader drains then sees EndOfStream.
    pub fn close(&self) {
        let mut st = self.shared.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.not_empty.notify_all();
        self.shared.not_full.notify_all();
    }
}

impl Drop for SstWriter {
    fn drop(&mut self) {
        self.close();
    }
}

impl SstReader {
    /// Block until a step is available or the stream ends.
    pub fn begin_step(&self) -> StepStatus {
        let mut st = self.shared.queue.lock().unwrap();
        loop {
            if let Some(f) = st.frames.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return StepStatus::Ok(Box::new(f));
            }
            if st.closed {
                return StepStatus::EndOfStream;
            }
            st = self.shared.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking-ish variant with a timeout.
    pub fn try_begin_step(&self, timeout: Duration) -> StepStatus {
        let mut st = self.shared.queue.lock().unwrap();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(f) = st.frames.pop_front() {
                drop(st);
                self.shared.not_full.notify_one();
                return StepStatus::Ok(Box::new(f));
            }
            if st.closed {
                return StepStatus::EndOfStream;
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return StepStatus::NotReady;
            }
            let (guard, _timeout_res) =
                self.shared.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Steps currently buffered (observability).
    pub fn depth(&self) -> usize {
        self.shared.queue.lock().unwrap().frames.len()
    }
}

impl Drop for SstReader {
    fn drop(&mut self) {
        // Unblock a writer stuck in put_step.
        let mut st = self.shared.queue.lock().unwrap();
        st.closed = true;
        drop(st);
        self.shared.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn frame(step: u64) -> StepFrame {
        StepFrame::new(0, 0, step)
    }

    #[test]
    fn fifo_order_and_eos() {
        let (w, r) = sst_channel(4);
        for s in 0..3 {
            w.put_step(frame(s));
        }
        w.close();
        for s in 0..3 {
            match r.begin_step() {
                StepStatus::Ok(f) => assert_eq!(f.step, s),
                other => panic!("expected step, got {other:?}"),
            }
        }
        assert_eq!(r.begin_step(), StepStatus::EndOfStream);
    }

    #[test]
    fn backpressure_blocks_writer() {
        let (w, r) = sst_channel(2);
        w.put_step(frame(0));
        w.put_step(frame(1));
        let handle = thread::spawn(move || {
            w.put_step(frame(2)); // blocks until reader drains
            w.writer_waits()
        });
        thread::sleep(Duration::from_millis(50));
        assert_eq!(r.depth(), 2);
        match r.begin_step() {
            StepStatus::Ok(f) => assert_eq!(f.step, 0),
            other => panic!("{other:?}"),
        }
        let waits = handle.join().unwrap();
        assert!(waits >= 1, "writer should have waited");
    }

    #[test]
    fn try_begin_step_times_out() {
        let (_w, r) = sst_channel(1);
        assert_eq!(
            r.try_begin_step(Duration::from_millis(10)),
            StepStatus::NotReady
        );
    }

    #[test]
    fn reader_drop_unblocks_writer() {
        let (w, r) = sst_channel(1);
        w.put_step(frame(0));
        let handle = thread::spawn(move || {
            w.put_step(frame(1)); // would block forever without drop handling
        });
        thread::sleep(Duration::from_millis(30));
        drop(r);
        handle.join().unwrap();
    }

    #[test]
    fn cross_thread_stream() {
        let (w, r) = sst_channel(3);
        let producer = thread::spawn(move || {
            for s in 0..100 {
                w.put_step(frame(s));
            }
        });
        let mut seen = 0u64;
        loop {
            match r.begin_step() {
                StepStatus::Ok(f) => {
                    assert_eq!(f.step, seen);
                    seen += 1;
                }
                StepStatus::EndOfStream => break,
                StepStatus::NotReady => unreachable!(),
            }
        }
        assert_eq!(seen, 100);
        producer.join().unwrap();
    }
}
