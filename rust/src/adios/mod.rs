//! Step-based streaming substrate — the ADIOS2 analogue.
//!
//! The paper moves TAU trace data through ADIOS2 with two engines:
//! **SST** (in-situ, step-based stream read concurrently by Chimbuko) and
//! **BP** (dump to disk — the "TAU only" baseline). We implement both
//! contracts:
//!
//! * [`sst`] — bounded, backpressured in-process step streams (one writer,
//!   one reader per rank stream), with begin/end step framing;
//! * [`bp`] — a file engine writing the [`binfmt`](crate::trace::binfmt)
//!   codec and counting bytes for the Fig 9 size axes.

pub mod bp;
pub mod sst;

pub use bp::BpWriter;
pub use sst::{sst_channel, SstReader, SstWriter, StepStatus};
