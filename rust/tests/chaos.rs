//! Chaos-plane integration: real server children spawned from the built
//! `chimbuko` binary, killed mid-run, respawned into the same endpoint
//! slot, with every lost record accounted for (`rust/docs/chaos.md`).
//!
//! This is the ONLY test binary that runs *live* fault plans — the
//! plan registry is process-global, so library unit tests stay inert
//! and the injection tests live here, in their own process. Server-side
//! injection rides to the children via `CHIMBUKO_CHAOS`.
//!
//! Every test skips loudly (never silently fails) when the `chimbuko`
//! binary is not built; `cargo test` builds it alongside the tests, so
//! in CI they always run.

use chimbuko::coordinator::{pick_addr, ChildSpec, Supervisor};
use chimbuko::exp::{find_chimbuko_bin, run_chaos};
use chimbuko::provdb::ProvClient;
use chimbuko::provenance::{ProvRecord, RecordFormat};
use chimbuko::stats::RunStats;
use chimbuko::util::fault::{FaultPlan, KillTarget};
use std::path::PathBuf;

fn bin_or_skip(test: &str) -> Option<PathBuf> {
    match find_chimbuko_bin() {
        Some(b) => Some(b),
        None => {
            eprintln!("{test}: SKIPPED — chimbuko binary not found (set CHIMBUKO_BIN)");
            None
        }
    }
}

fn rec(i: u64) -> ProvRecord {
    let entry = i * 1_000;
    ProvRecord {
        call_id: i,
        app: 0,
        rank: (i % 4) as u32,
        thread: 0,
        fid: (i % 6) as u32,
        func: format!("F{}", i % 6),
        step: i / 8,
        entry_us: entry,
        exit_us: entry + 500,
        inclusive_us: 500,
        exclusive_us: 250,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        label: "normal".to_string(),
        score: 1.0,
    }
}

/// Kill → same-slot respawn → state re-seed, against a live
/// `ps-shard-server` child.
#[test]
fn supervisor_respawns_a_killed_shard_into_its_slot() {
    let Some(bin) = bin_or_skip("supervisor_respawns_a_killed_shard_into_its_slot") else {
        return;
    };
    let mut sup = Supervisor::new(bin);
    let addr = pick_addr().unwrap();
    sup.spawn(ChildSpec::ps_shard(0, 1, &addr)).unwrap();
    sup.await_ready().unwrap();
    assert!(sup.is_alive(KillTarget::PsShard, 0));

    // Seed some state, checkpoint it, then crash the child.
    let mut st = RunStats::new();
    for v in [1.0, 2.0, 4.0] {
        st.push(v);
    }
    sup.ps_install(0, 1, &[((0u32, 7u32), st)]).unwrap();
    let ckpt = sup.ps_extract(0, 1).unwrap();
    assert_eq!(ckpt.len(), 1, "installed state must be visible in the dump");

    let killed_at = sup.kill(KillTarget::PsShard, 0).unwrap();
    assert_eq!(killed_at, addr, "kill reports the slot's endpoint");
    assert!(!sup.is_alive(KillTarget::PsShard, 0));

    sup.respawn(KillTarget::PsShard, 0).unwrap();
    assert!(sup.is_alive(KillTarget::PsShard, 0));
    assert_eq!(sup.addr_of(KillTarget::PsShard, 0), Some(addr.as_str()));
    assert_eq!(sup.restarts(KillTarget::PsShard, 0), 1);

    // The respawned shard is empty (crash lost RAM state) until the
    // checkpoint is re-seeded — then the dump is bit-identical.
    assert!(sup.ps_extract(0, 1).unwrap().is_empty());
    sup.ps_install(0, 1, &ckpt).unwrap();
    assert_eq!(sup.ps_extract(0, 1).unwrap(), ckpt);
    sup.stop_all();
}

/// Server-side sever injection (plan handed through `CHIMBUKO_CHAOS`):
/// the provDB child drops connections on a seeded cadence; the client's
/// resend-once path heals each one, and whatever survives neither
/// attempt lands in the `inflight_lost` ledger — the retained count
/// always equals written − counted-lost.
#[test]
fn server_side_severs_are_healed_or_counted() {
    let Some(bin) = bin_or_skip("server_side_severs_are_healed_or_counted") else {
        return;
    };
    let mut plan = FaultPlan::kills_only(11, vec![]);
    plan.sever_every = 7;
    let mut sup = Supervisor::new(bin).with_plan(&plan);
    let addr = pick_addr().unwrap();
    let dir = std::env::temp_dir().join(format!("chimbuko-chaos-sever-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    sup.spawn(ChildSpec::provdb(0, 1, &addr, &dir)).unwrap();
    sup.await_ready().unwrap();

    // Connecting can itself be severed mid-handshake — retry.
    let mut client = None;
    for _ in 0..20 {
        match ProvClient::connect_with(&addr, 4, RecordFormat::Binary) {
            Ok(c) => {
                client = Some(c);
                break;
            }
            Err(_) => std::thread::sleep(std::time::Duration::from_millis(20)),
        }
    }
    let mut c = client.expect("provdb connect kept getting severed");

    let mut written = 60u64;
    for i in 0..written {
        // A batch whose send and one resend are both severed is counted
        // lost; the error is the client telling us it counted.
        let _ = c.append(&rec(i));
    }
    // Drain to a clean barrier. A severed `KIND_FLUSH` leaves a dead
    // stream that only a batched send redials, so push one extra record
    // per failed attempt to force the heal.
    let mut flushed = false;
    for extra in 0..50u64 {
        if c.flush().is_ok() {
            flushed = true;
            break;
        }
        let _ = c.append(&rec(1_000 + extra));
        written += 1;
    }
    assert!(flushed, "flush barrier never landed despite heal attempts");
    // Query through a fresh connection: a severed stats reply kills the
    // stream, and a query-only client has no batched send to heal it.
    let stats = loop {
        if let Ok(mut q) = ProvClient::connect_with(&addr, 4, RecordFormat::Binary) {
            if let Ok(s) = q.stats() {
                break s;
            }
        }
        std::thread::sleep(std::time::Duration::from_millis(20));
    };
    assert!(stats.records > 0, "some batches must land despite severs");
    assert_eq!(
        stats.records,
        written - c.inflight_lost(),
        "retained must equal written minus the counted in-flight loss"
    );
    sup.stop_all();
    let _ = std::fs::remove_dir_all(&dir);
}

/// The full scenario: kill one PS shard and the provDB shard mid-run.
/// `run_chaos` internally asserts the bounded-loss guarantees (final PS
/// state bit-identical to an unfaulted control run, provDB ledger
/// exact); this test checks the reported rows on top.
#[test]
fn chaos_scenario_kills_and_heals_both_shard_types() {
    let Some(bin) = bin_or_skip("chaos_scenario_kills_and_heals_both_shard_types") else {
        return;
    };
    let res = run_chaos(&bin, 2, 3, 9, 7).expect("chaos scenario");
    assert_eq!(res.rows.len(), 2, "one row per scheduled kill");
    assert!(res.ps_state_identical);
    assert!(res.ps_sync_lost > 0, "the dropped sub-frame must be counted");
    assert!(res.prov_lost > 0, "the in-flight window must be counted");
    assert_eq!(res.prov_records, res.prov_written - res.prov_lost);

    let ps = res.rows.iter().find(|r| r.target == "ps").expect("ps row");
    assert_eq!(ps.at_step, 3, "seeded schedule: PS kill at steps/3");
    assert!(ps.records_lost > 0, "transient PS loss is visible in the row");
    assert!(ps.recovery_ms > 0.0);

    let pd = res.rows.iter().find(|r| r.target == "provdb").expect("provdb row");
    assert_eq!(pd.at_step, 6, "seeded schedule: provDB kill at 2·steps/3");
    assert_eq!(pd.records_lost, res.prov_lost, "all permanent loss is the down window");
    assert!(pd.recovery_ms > 0.0);
}
