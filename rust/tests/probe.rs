//! Probe subsystem acceptance properties:
//!
//! 1. **Query agreement** — compiled probe predicates agree with
//!    `ProvQuery::matches` on the expressible filter subset (app / rank /
//!    fid / step / step ranges / time ranges / anomalies / min-score /
//!    label), over records with unicode custom labels and edge-case
//!    scores, for hundreds of randomly drawn queries.
//! 2. **Hostility** — random source strings, mutated wire encodings, and
//!    random bytecode are rejected or execute within the verifier budget;
//!    nothing panics.
//! 3. **Wire subscriptions** — a probe installed over the TCP protocol
//!    filters server-side: the probe query returns bytes bit-identical
//!    to the equivalent `ProvQuery` scan, and the per-probe counters
//!    prove non-matching records never crossed the wire.
//! 4. **Aggregator triggers** — a trigger probe on the PS aggregator
//!    lands the matching global-event record in provDB at flag time,
//!    with no publish/dump cycle ever running; and a full driver run
//!    with `[probe] trigger` accounts trigger pushes consistently.

use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, Workflow};
use chimbuko::probe::bytecode::{Const, Program, MAX_CODE, OP_RET};
use chimbuko::probe::{vm, Probe};
use chimbuko::provdb::{spawn_store, ProvClient, ProvDbTcpServer, Retention};
use chimbuko::provenance::{codec, ProvQuery, ProvRecord};
use chimbuko::ps::{spawn_with, PsOpts, StepStat};
use chimbuko::util::rng::Rng;
use chimbuko::util::wire::Cursor;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Labels seen in the stream: the builtin three plus unicode custom
/// labels (anomalous by definition — `label != "normal"`).
const LABELS: [&str; 6] =
    ["normal", "anomaly_high", "anomaly_low", "ünïcode_läbel", "spike-异常", "tail☂"];

/// Scores include negatives, a huge finite value, and +inf — the query
/// and the VM must order all of them identically.
const SCORES: [f64; 7] = [0.0, -3.25, 1.5, 6.5, 9.0, 1e300, f64::INFINITY];

fn record(rng: &mut Rng, i: u64) -> ProvRecord {
    let entry = rng.range_u64(0, 20) * 1_000;
    let dur = rng.range_u64(10, 3_000);
    let label = if rng.chance(0.6) { LABELS[0] } else { LABELS[1 + rng.usize(5)] };
    ProvRecord {
        call_id: i,
        app: (i % 2) as u32,
        rank: rng.usize(5) as u32,
        thread: rng.usize(2) as u32,
        fid: rng.usize(6) as u32,
        func: format!("FN_{}", rng.usize(6)),
        step: rng.usize(4) as u64,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: dur / 2,
        depth: rng.usize(3) as u32,
        parent: if rng.chance(0.5) { Some(i.saturating_sub(1)) } else { None },
        n_children: rng.usize(3) as u32,
        n_messages: rng.usize(4) as u32,
        msg_bytes: rng.range_u64(0, 4096),
        label: label.to_string(),
        score: SCORES[rng.usize(SCORES.len())],
    }
}

fn encode(r: &ProvRecord) -> Vec<u8> {
    let mut buf = Vec::new();
    codec::encode(r, &mut buf);
    buf
}

/// Probe source equivalent to the predicate part of `q` (ordering and
/// limits are not predicates and have no probe counterpart).
fn probe_source_of(q: &ProvQuery) -> String {
    let mut conj: Vec<String> = Vec::new();
    if let Some(a) = q.app {
        conj.push(format!("app == {a}"));
    }
    if let Some((a, k)) = q.rank {
        conj.push(format!("app == {a} && rank == {k}"));
    }
    if let Some((a, f)) = q.fid {
        conj.push(format!("app == {a} && fid == {f}"));
    }
    if let Some(s) = q.step {
        conj.push(format!("step == {s}"));
    }
    if let Some((lo, hi)) = q.step_range {
        conj.push(format!("step >= {lo} && step <= {hi}"));
    }
    if q.anomalies_only {
        conj.push("anomaly".to_string());
    }
    if let Some(m) = q.min_score {
        // `{:?}` round-trips f64 exactly; the lexer accepts e-notation
        // and the parser accepts unary minus.
        conj.push(format!("score >= {m:?}"));
    }
    if let Some(l) = &q.label {
        conj.push(format!("label == \"{l}\""));
    }
    if let Some((lo, hi)) = q.ts_range {
        // ProvQuery::matches overlap semantics.
        conj.push(format!("exit_us >= {lo} && entry_us <= {hi}"));
    }
    if conj.is_empty() {
        "fn:*.*:exit".to_string()
    } else {
        format!("fn:*.*:exit / {} /", conj.join(" && "))
    }
}

fn random_query(rng: &mut Rng) -> ProvQuery {
    let mut q = ProvQuery::default();
    if rng.chance(0.3) {
        q.app = Some(rng.usize(3) as u32);
    }
    if rng.chance(0.3) {
        q.rank = Some((rng.usize(2) as u32, rng.usize(6) as u32));
    }
    if rng.chance(0.3) {
        q.fid = Some((rng.usize(2) as u32, rng.usize(7) as u32));
    }
    if rng.chance(0.25) {
        q.step = Some(rng.usize(5) as u64);
    }
    if rng.chance(0.25) {
        let lo = rng.range_u64(0, 3);
        q.step_range = Some((lo, lo + rng.range_u64(0, 3)));
    }
    if rng.chance(0.25) {
        let lo = rng.range_u64(0, 15_000);
        q.ts_range = Some((lo, lo + rng.range_u64(0, 8_000)));
    }
    if rng.chance(0.3) {
        q.anomalies_only = true;
    }
    if rng.chance(0.35) {
        q.min_score = Some([0.0, -2.5, 1.5, 6.0, 9.0, 1e300][rng.usize(6)]);
    }
    if rng.chance(0.3) {
        q.label = Some(LABELS[rng.usize(LABELS.len())].to_string());
    }
    q
}

#[test]
fn compiled_probes_agree_with_provquery_on_expressible_subset() {
    let mut rng = Rng::new(0x9E0B);
    let records: Vec<ProvRecord> = (0..400).map(|i| record(&mut rng, i)).collect();
    let encoded: Vec<Vec<u8>> = records.iter().map(encode).collect();

    let mut nontrivial = 0usize;
    for qi in 0..300 {
        let q = random_query(&mut rng);
        let src = probe_source_of(&q);
        let p = Probe::compile(&src)
            .unwrap_or_else(|e| panic!("query #{qi} source `{src}` failed to compile: {e:#}"));
        let mut any = false;
        for (r, buf) in records.iter().zip(&encoded) {
            let want = q.matches(r);
            assert_eq!(
                p.matches(buf),
                want,
                "query #{qi} `{src}` diverged on record {} (label {:?}, score {})",
                r.call_id,
                r.label,
                r.score
            );
            any |= want;
        }
        nontrivial += any as usize;
    }
    // The agreement must not be vacuous: a healthy share of the drawn
    // queries matched at least one record.
    assert!(nontrivial > 50, "only {nontrivial}/300 queries matched anything");
}

#[test]
fn hostile_sources_and_bytecode_never_panic() {
    let mut rng = Rng::new(0xF422);
    let sample = encode(&record(&mut rng, 7));

    // (a) Random token-soup sources: compile must return Ok or Err —
    // never panic — and accepted programs stay within the code budget.
    let frags = [
        "probe", "fn", ":", ".", "*", "/", "sample", "%", "{", "}", "(", ")", ";", "score",
        "label", "func", "anomaly", "step", "&&", "||", "!", "==", "!=", "<=", ">=", "\"",
        "0.5", "18446744073709551615", "1e308", "x", "ü", "#", "\n", " ", "-", "+", "capture",
        "record", "stack", "entry", "exit", "\\", "p0",
    ];
    for _ in 0..2_000 {
        let mut s = String::new();
        for _ in 0..rng.usize(40) {
            s.push_str(frags[rng.usize(frags.len())]);
        }
        if let Ok(probes) = Probe::compile_all(&s) {
            for p in &probes {
                p.program.verify().expect("accepted program must verify");
                assert!(p.program.code.len() <= MAX_CODE);
                let _ = p.matches(&sample);
            }
        }
    }
    // Raw bytes forced into a lossy string exercise the lexer's byte
    // handling on arbitrary junk.
    for _ in 0..500 {
        let bytes: Vec<u8> = (0..rng.usize(120)).map(|_| rng.usize(256) as u8).collect();
        let _ = Probe::compile_all(&String::from_utf8_lossy(&bytes));
    }

    // (b) Mutated wire encodings: truncations at every length plus
    // random byte flips. A decode that slips through must still verify
    // and evaluate without panicking.
    let base = Probe::compile(
        "probe hot: fn:0.md_force:exit / score > 0.9 && label == \"weird\" / sample 3/7 { capture(stack); }",
    )
    .unwrap();
    let mut wire = Vec::new();
    base.to_wire(&mut wire);
    for n in 0..wire.len() {
        let _ = Probe::from_wire(&mut Cursor::new(&wire[..n]));
    }
    for _ in 0..4_000 {
        let mut m = wire.clone();
        for _ in 0..1 + rng.usize(3) {
            let i = rng.usize(m.len());
            m[i] = rng.usize(256) as u8;
        }
        if let Ok(p) = Probe::from_wire(&mut Cursor::new(&m)) {
            p.program.verify().expect("from_wire must only return verified programs");
            let _ = p.matches(&sample);
        }
    }

    // (c) Random bytecode straight at the verifier: acceptance implies a
    // bounded, panic-free evaluation.
    for _ in 0..4_000 {
        let consts: Vec<Const> = (0..rng.usize(5))
            .map(|_| match rng.usize(3) {
                0 => Const::U(rng.range_u64(0, 1 << 40)),
                1 => Const::F(rng.f64() * 100.0 - 50.0),
                _ => Const::S("läbel".repeat(rng.usize(3))),
            })
            .collect();
        let mut code: Vec<u8> = (0..rng.usize(24)).map(|_| rng.usize(20) as u8).collect();
        if rng.chance(0.8) {
            code.push(OP_RET);
        }
        let prog = Program { consts, code };
        if prog.verify().is_ok() {
            let _ = vm::eval(&prog, &sample);
        }
    }
}

#[test]
fn wire_installed_probe_filters_subscriptions_server_side() {
    let mut rng = Rng::new(0x50B5);
    let records: Vec<ProvRecord> = (0..500).map(|i| record(&mut rng, i)).collect();

    let (store, handle) = spawn_store(None, 3, Retention::default()).unwrap();
    let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
    let mut client = ProvClient::connect(&srv.addr().to_string()).unwrap();
    for r in &records {
        client.append(r).unwrap();
    }
    client.flush().unwrap();

    // Install over the wire; probe ≡ ProvQuery { min_score, anomalies_only }.
    let hot =
        Probe::compile("probe hot: fn:*.*:exit / score >= 6.0 && anomaly /").unwrap();
    client.install_probe(&hot).unwrap();
    let via_probe = client.probe_query_encoded("hot").unwrap();
    let q = ProvQuery { min_score: Some(6.0), anomalies_only: true, ..Default::default() };
    let want = store.query_encoded(&q);
    assert!(!via_probe.is_empty(), "stream must contain hot anomalies");
    assert!(via_probe.len() < records.len(), "probe must actually filter");
    assert_eq!(via_probe, want, "wire probe query must be bit-identical to the query scan");

    // The per-probe counters prove non-matching records never crossed
    // the wire: everything matched was pushed, nothing else.
    let wire_bytes: u64 = via_probe.iter().map(|b| b.len() as u64).sum();
    let infos = client.list_probes().unwrap();
    assert_eq!(infos.len(), 1);
    assert_eq!(infos[0].name, "hot");
    assert_eq!(infos[0].matches, via_probe.len() as u64);
    assert_eq!(infos[0].shed, 0);
    assert_eq!(infos[0].pushed_records, via_probe.len() as u64);
    assert_eq!(infos[0].pushed_bytes, wire_bytes);

    // Decoded probe replies equal the decoded query replies, and the
    // counters accumulate across scans.
    assert_eq!(client.probe_query("hot").unwrap(), client.query(&q).unwrap());
    let infos = client.list_probes().unwrap();
    assert_eq!(infos[0].pushed_records, 2 * via_probe.len() as u64);

    // A 0/2 sampling probe sheds every match server-side: the reply is
    // empty and the shed counter carries the proof.
    client
        .install_probe(&Probe::compile("probe none: fn:*.*:exit / anomaly / sample 0/2").unwrap())
        .unwrap();
    assert!(client.probe_query_encoded("none").unwrap().is_empty());
    let infos = client.list_probes().unwrap();
    let none = infos.iter().find(|i| i.name == "none").unwrap();
    assert!(none.matches > 0);
    assert_eq!(none.shed, none.matches);
    assert_eq!(none.pushed_records, 0);
    assert_eq!(none.pushed_bytes, 0);

    assert!(client.remove_probe("none").unwrap());
    assert!(!client.remove_probe("none").unwrap());
    assert_eq!(client.list_probes().unwrap().len(), 1);

    drop(srv);
    handle.join();
}

#[test]
fn aggregator_trigger_probe_lands_in_provdb_without_a_dump() {
    let (store, handle) = spawn_store(None, 1, Retention::default()).unwrap();
    let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
    let addr = srv.addr().to_string();

    // The forwarder the driver spawns when `[probe] trigger` is set:
    // per-record append + flush so triggered records land immediately.
    let (ttx, trx) = std::sync::mpsc::channel::<ProvRecord>();
    let fwd = std::thread::spawn(move || {
        let mut c = ProvClient::connect(&addr).unwrap();
        let mut pushed = 0u64;
        while let Ok(rec) = trx.recv() {
            c.append(&rec).unwrap();
            c.flush().unwrap();
            pushed += 1;
        }
        pushed
    });

    let probe = Probe::compile(
        "probe trig: fn:*.*:exit / func == \"workflow.global_event\" && score > 3.0 /",
    )
    .unwrap();
    let (ps_client, ps_handle) = spawn_with(PsOpts {
        shards: 1,
        // No publish/sync period ever elapses — delivery below can only
        // have come from the flag-time trigger path.
        publish_every: usize::MAX >> 1,
        reports_per_step: 1,
        trigger_probes: vec![Arc::new(probe)],
        trigger_tx: Some(ttx),
        ..PsOpts::default()
    })
    .unwrap();
    let report = |step: u64, anoms: u64| {
        ps_client.report(StepStat {
            app: 0,
            rank: 0,
            step,
            n_executions: 100,
            n_anomalies: anoms,
            ts_range: (step, step + 1),
        });
    };
    for step in 0..10 {
        report(step, u64::from(step % 3 == 0));
    }
    report(10, 25); // burst → global event

    let q = ProvQuery { label: Some("global_event".into()), ..Default::default() };
    let deadline = Instant::now() + Duration::from_secs(10);
    let got = loop {
        let got = store.query(&q);
        if !got.is_empty() {
            break got;
        }
        assert!(Instant::now() < deadline, "trigger record never reached provDB");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert_eq!(got.len(), 1);
    assert_eq!(got[0].step, 10);
    assert_eq!(got[0].func, "workflow.global_event");
    assert_eq!(got[0].msg_bytes, 25);
    assert!(got[0].score > 3.0, "score {}", got[0].score);
    // Nothing else ever flowed into the service: the triggered record is
    // the only record it holds.
    assert_eq!(store.stats().records, 1);

    ps_client.shutdown();
    ps_handle.join();
    assert_eq!(fwd.join().unwrap(), 1, "exactly one trigger push");
    drop(srv);
    handle.join();
}

#[test]
fn driver_trigger_probe_accounts_consistently_end_to_end() {
    let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
    let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
    let cfg = Config {
        ranks: 8,
        apps: 2,
        steps: 12,
        calls_per_step: 130,
        out_dir: String::new(),
        provdb_addr: srv.addr().to_string(),
        probe_trigger: "probe burst: fn:*.*:exit / func == \"workflow.global_event\" /"
            .to_string(),
        ..Config::default()
    };
    let w = Workflow::nwchem(&cfg);
    let report = run(&cfg, &w, Mode::TauChimbuko).unwrap();
    assert!(report.total_kept > 0);

    // Whether or not this workload flags global events, the books must
    // balance: every trigger push is a `global_event` record in the
    // store, on top of the per-rank kept records.
    let triggered =
        store.query(&ProvQuery { label: Some("global_event".into()), ..Default::default() });
    assert_eq!(triggered.len() as u64, report.trigger_pushed);
    for r in &triggered {
        assert_eq!(r.func, "workflow.global_event");
        assert_eq!((r.app, r.rank, r.fid), (u32::MAX, u32::MAX, u32::MAX));
    }
    let stats = store.stats();
    assert_eq!(stats.records, report.total_kept + report.trigger_pushed);

    drop(srv);
    handle.join();
}
