//! Cross-module property tests (mini-proptest harness): invariants that
//! must hold for *any* generated workload, not just the curated cases.

use chimbuko::ad::{DetectEngine, DetectorConfig, OnNodeAd, RustDetector, StackBuilder};
use chimbuko::stats::{RunStats, StatsTable};
use chimbuko::trace::binfmt;
use chimbuko::trace::event::{Event, FuncKind};
use chimbuko::trace::nwchem::{self, InjectionConfig};
use chimbuko::trace::RankTracer;
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;

fn rand_injection(rng: &mut Rng) -> InjectionConfig {
    InjectionConfig {
        forces_delay_prob: rng.range_f64(0.0, 0.05),
        rank0_straggle_prob: rng.range_f64(0.0, 0.1),
        getxbl_tail_prob: rng.range_f64(0.0, 0.05),
    }
}

#[test]
fn prop_generated_frames_always_wellformed() {
    check(
        "frames-wellformed",
        PropConfig { cases: 60, seed: 0xF00D, max_size: 6 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let world = 1 + rng.usize(16) as u32;
            let rank = rng.usize(world as usize) as u32;
            let unfiltered = rng.chance(0.5);
            let mut t = RankTracer::new(g, 0, rank, world, unfiltered, rng.fork(1));
            for _ in 0..3 {
                let f = t.step();
                if !f.is_sorted() {
                    return Err("frame not time-sorted".into());
                }
                let mut depth = 0i64;
                for e in &f.events {
                    match e {
                        Event::Func(fe) => {
                            depth += if fe.kind == FuncKind::Entry { 1 } else { -1 };
                            if depth < 0 {
                                return Err("EXIT before ENTRY".into());
                            }
                        }
                        Event::Comm(c) => {
                            if c.partner >= world {
                                return Err(format!(
                                    "partner {} outside world {}",
                                    c.partner, world
                                ));
                            }
                        }
                    }
                }
                if depth != 0 {
                    return Err(format!("unbalanced frame: depth {depth}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_binfmt_roundtrip_any_frame() {
    check(
        "binfmt-roundtrip",
        PropConfig { cases: 60, seed: 0xBEEF, max_size: 8 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 0, 4, rng.chance(0.5), rng.fork(2));
            let f = t.step();
            let mut buf = Vec::new();
            binfmt::write_frame(&mut buf, &f).map_err(|e| e.to_string())?;
            let back = binfmt::read_frame(&mut buf.as_slice())
                .map_err(|e| e.to_string())?
                .ok_or("eof")?;
            if back.events != f.events {
                return Err("events changed across roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stack_exclusive_never_exceeds_inclusive() {
    check(
        "exclusive-le-inclusive",
        PropConfig { cases: 40, seed: 0xCAFE, max_size: 6 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 1, 8, false, rng.fork(3));
            let mut sb = StackBuilder::new(0, 1);
            for _ in 0..4 {
                for r in sb.process(&t.step()) {
                    if r.exclusive_us > r.inclusive_us() {
                        return Err(format!(
                            "exclusive {} > inclusive {} for fid {}",
                            r.exclusive_us,
                            r.inclusive_us(),
                            r.fid
                        ));
                    }
                    if r.exit_us_check() {
                        return Err("exit before entry".into());
                    }
                }
            }
            Ok(())
        },
    );
}

trait ExitCheck {
    fn exit_us_check(&self) -> bool;
}

impl ExitCheck for chimbuko::ad::ExecRecord {
    fn exit_us_check(&self) -> bool {
        self.exit_ts < self.entry_ts
    }
}

#[test]
fn prop_detector_stats_match_stream_stats() {
    // Feeding batches through the detector must produce exactly the same
    // per-function moments as a single Welford stream over all values.
    check(
        "detector-stats-stream",
        PropConfig { cases: 40, seed: 0xD00D, max_size: 200 },
        |rng, size| {
            let mut det = RustDetector::new(DetectorConfig::default());
            let mut reference = StatsTable::new();
            let mut id = 0u64;
            for _batch in 0..4 {
                let records: Vec<chimbuko::ad::ExecRecord> = (0..size.max(1))
                    .map(|_| {
                        let fid = rng.usize(6) as u32;
                        let dur = rng.lognormal(5.0, 1.0).max(1.0) as u64;
                        reference.push(fid, dur as f64);
                        id += 1;
                        mk_rec(fid, dur, id)
                    })
                    .collect();
                DetectEngine::detect(&mut det, records);
            }
            for (fid, want) in reference.iter() {
                let got = det.view().get(fid).ok_or("missing fid")?;
                if got.count() != want.count() {
                    return Err("count mismatch".into());
                }
                if (got.mean() - want.mean()).abs() > 1e-6 * (1.0 + want.mean()) {
                    return Err("mean mismatch".into());
                }
                if (got.variance() - want.variance()).abs()
                    > 1e-5 * (1.0 + want.variance())
                {
                    return Err("variance mismatch".into());
                }
            }
            Ok(())
        },
    );
}

fn mk_rec(fid: u32, dur: u64, id: u64) -> chimbuko::ad::ExecRecord {
    chimbuko::ad::ExecRecord {
        call_id: id,
        app: 0,
        rank: 0,
        thread: 0,
        fid,
        step: 0,
        entry_ts: id * 100_000,
        exit_ts: id * 100_000 + dur,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        exclusive_us: dur,
    }
}

#[test]
fn prop_kept_window_bounds() {
    // kept ≤ anomalies × (2k + 1) and every anomaly is kept.
    check(
        "kept-window-bounds",
        PropConfig { cases: 30, seed: 0xAB1E, max_size: 8 },
        |rng, size| {
            let k = rng.usize(8);
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 0, 4, false, rng.fork(4));
            let mut ad = OnNodeAd::new(
                0,
                0,
                k,
                Box::new(RustDetector::new(DetectorConfig::default())),
            );
            let mut anoms = 0u64;
            let mut kept = 0u64;
            for _ in 0..6 {
                let res = ad.process_step(&t.step());
                anoms += res.n_anomalies;
                kept += res.kept.len() as u64;
                let kept_anoms =
                    res.kept.iter().filter(|l| l.label.is_anomaly()).count() as u64;
                if kept_anoms != res.n_anomalies {
                    return Err(format!(
                        "anomaly missing from kept: {} vs {}",
                        kept_anoms, res.n_anomalies
                    ));
                }
            }
            if kept > anoms * (2 * k as u64 + 1) {
                return Err(format!("kept {kept} exceeds window bound for {anoms} anomalies"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ps_merge_order_independent() {
    // The parameter server's global stats must not depend on sync order.
    check(
        "ps-order-independent",
        PropConfig { cases: 30, seed: 0x07DE, max_size: 64 },
        |rng, size| {
            let n_ranks = 2 + rng.usize(6);
            let mut deltas: Vec<StatsTable> = Vec::new();
            for _ in 0..n_ranks {
                let mut t = StatsTable::new();
                for _ in 0..size.max(2) {
                    t.push(rng.usize(5) as u32, rng.lognormal(4.0, 0.8));
                }
                deltas.push(t);
            }
            let merge_in_order = |order: &[usize]| -> StatsTable {
                let mut global = StatsTable::new();
                for &i in order {
                    global.merge(&deltas[i]);
                }
                global
            };
            let fwd: Vec<usize> = (0..n_ranks).collect();
            let mut shuffled = fwd.clone();
            rng.shuffle(&mut shuffled);
            let a = merge_in_order(&fwd);
            let b = merge_in_order(&shuffled);
            for (fid, sa) in a.iter() {
                let sb: &RunStats = b.get(fid).ok_or("missing fid")?;
                if sa.count() != sb.count() {
                    return Err("count order-dependent".into());
                }
                if (sa.mean() - sb.mean()).abs() > 1e-9 * (1.0 + sa.mean().abs()) {
                    return Err("mean order-dependent".into());
                }
                if (sa.m2() - sb.m2()).abs() > 1e-6 * (1.0 + sa.m2().abs()) {
                    return Err("m2 order-dependent".into());
                }
            }
            Ok(())
        },
    );
}
