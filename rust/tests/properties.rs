//! Cross-module property tests (mini-proptest harness): invariants that
//! must hold for *any* generated workload, not just the curated cases.

use chimbuko::ad::{DetectEngine, DetectorConfig, OnNodeAd, RustDetector, StackBuilder};
use chimbuko::ps::{AggNodeLoad, GlobalEvent, RankSummary, ShardLoad, StepStat, VizSnapshot};
use chimbuko::stats::{RunStats, StatsTable};
use chimbuko::trace::binfmt;
use chimbuko::trace::event::{Event, FuncKind};
use chimbuko::trace::nwchem::{self, InjectionConfig};
use chimbuko::trace::RankTracer;
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;

fn rand_injection(rng: &mut Rng) -> InjectionConfig {
    InjectionConfig {
        forces_delay_prob: rng.range_f64(0.0, 0.05),
        rank0_straggle_prob: rng.range_f64(0.0, 0.1),
        getxbl_tail_prob: rng.range_f64(0.0, 0.05),
    }
}

#[test]
fn prop_generated_frames_always_wellformed() {
    check(
        "frames-wellformed",
        PropConfig { cases: 60, seed: 0xF00D, max_size: 6 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let world = 1 + rng.usize(16) as u32;
            let rank = rng.usize(world as usize) as u32;
            let unfiltered = rng.chance(0.5);
            let mut t = RankTracer::new(g, 0, rank, world, unfiltered, rng.fork(1));
            for _ in 0..3 {
                let f = t.step();
                if !f.is_sorted() {
                    return Err("frame not time-sorted".into());
                }
                let mut depth = 0i64;
                for e in &f.events {
                    match e {
                        Event::Func(fe) => {
                            depth += if fe.kind == FuncKind::Entry { 1 } else { -1 };
                            if depth < 0 {
                                return Err("EXIT before ENTRY".into());
                            }
                        }
                        Event::Comm(c) => {
                            if c.partner >= world {
                                return Err(format!(
                                    "partner {} outside world {}",
                                    c.partner, world
                                ));
                            }
                        }
                    }
                }
                if depth != 0 {
                    return Err(format!("unbalanced frame: depth {depth}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_binfmt_roundtrip_any_frame() {
    check(
        "binfmt-roundtrip",
        PropConfig { cases: 60, seed: 0xBEEF, max_size: 8 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 0, 4, rng.chance(0.5), rng.fork(2));
            let f = t.step();
            let mut buf = Vec::new();
            binfmt::write_frame(&mut buf, &f).map_err(|e| e.to_string())?;
            let back = binfmt::read_frame(&mut buf.as_slice())
                .map_err(|e| e.to_string())?
                .ok_or("eof")?;
            if back.events != f.events {
                return Err("events changed across roundtrip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_stack_exclusive_never_exceeds_inclusive() {
    check(
        "exclusive-le-inclusive",
        PropConfig { cases: 40, seed: 0xCAFE, max_size: 6 },
        |rng, size| {
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 1, 8, false, rng.fork(3));
            let mut sb = StackBuilder::new(0, 1);
            for _ in 0..4 {
                for r in sb.process(&t.step()) {
                    if r.exclusive_us > r.inclusive_us() {
                        return Err(format!(
                            "exclusive {} > inclusive {} for fid {}",
                            r.exclusive_us,
                            r.inclusive_us(),
                            r.fid
                        ));
                    }
                    if r.exit_us_check() {
                        return Err("exit before entry".into());
                    }
                }
            }
            Ok(())
        },
    );
}

trait ExitCheck {
    fn exit_us_check(&self) -> bool;
}

impl ExitCheck for chimbuko::ad::ExecRecord {
    fn exit_us_check(&self) -> bool {
        self.exit_ts < self.entry_ts
    }
}

#[test]
fn prop_detector_stats_match_stream_stats() {
    // Feeding batches through the detector must produce exactly the same
    // per-function moments as a single Welford stream over all values.
    check(
        "detector-stats-stream",
        PropConfig { cases: 40, seed: 0xD00D, max_size: 200 },
        |rng, size| {
            let mut det = RustDetector::new(DetectorConfig::default());
            let mut reference = StatsTable::new();
            let mut id = 0u64;
            for _batch in 0..4 {
                let records: Vec<chimbuko::ad::ExecRecord> = (0..size.max(1))
                    .map(|_| {
                        let fid = rng.usize(6) as u32;
                        let dur = rng.lognormal(5.0, 1.0).max(1.0) as u64;
                        reference.push(fid, dur as f64);
                        id += 1;
                        mk_rec(fid, dur, id)
                    })
                    .collect();
                DetectEngine::detect(&mut det, records);
            }
            for (fid, want) in reference.iter() {
                let got = det.view().get(fid).ok_or("missing fid")?;
                if got.count() != want.count() {
                    return Err("count mismatch".into());
                }
                if (got.mean() - want.mean()).abs() > 1e-6 * (1.0 + want.mean()) {
                    return Err("mean mismatch".into());
                }
                if (got.variance() - want.variance()).abs()
                    > 1e-5 * (1.0 + want.variance())
                {
                    return Err("variance mismatch".into());
                }
            }
            Ok(())
        },
    );
}

fn mk_rec(fid: u32, dur: u64, id: u64) -> chimbuko::ad::ExecRecord {
    chimbuko::ad::ExecRecord {
        call_id: id,
        app: 0,
        rank: 0,
        thread: 0,
        fid,
        step: 0,
        entry_ts: id * 100_000,
        exit_ts: id * 100_000 + dur,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        exclusive_us: dur,
    }
}

#[test]
fn prop_kept_window_bounds() {
    // kept ≤ anomalies × (2k + 1) and every anomaly is kept.
    check(
        "kept-window-bounds",
        PropConfig { cases: 30, seed: 0xAB1E, max_size: 8 },
        |rng, size| {
            let k = rng.usize(8);
            let inj = rand_injection(rng);
            let (g, _) = nwchem::md_grammar(size.max(1) as u32, &inj);
            let mut t = RankTracer::new(g, 0, 0, 4, false, rng.fork(4));
            let mut ad = OnNodeAd::new(
                0,
                0,
                k,
                Box::new(RustDetector::new(DetectorConfig::default())),
            );
            let mut anoms = 0u64;
            let mut kept = 0u64;
            for _ in 0..6 {
                let res = ad.process_step(&t.step());
                anoms += res.n_anomalies;
                kept += res.kept.len() as u64;
                let kept_anoms =
                    res.kept.iter().filter(|l| l.label.is_anomaly()).count() as u64;
                if kept_anoms != res.n_anomalies {
                    return Err(format!(
                        "anomaly missing from kept: {} vs {}",
                        kept_anoms, res.n_anomalies
                    ));
                }
            }
            if kept > anoms * (2 * k as u64 + 1) {
                return Err(format!("kept {kept} exceeds window bound for {anoms} anomalies"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_ps_merge_order_independent() {
    // The parameter server's global stats must not depend on sync order.
    check(
        "ps-order-independent",
        PropConfig { cases: 30, seed: 0x07DE, max_size: 64 },
        |rng, size| {
            let n_ranks = 2 + rng.usize(6);
            let mut deltas: Vec<StatsTable> = Vec::new();
            for _ in 0..n_ranks {
                let mut t = StatsTable::new();
                for _ in 0..size.max(2) {
                    t.push(rng.usize(5) as u32, rng.lognormal(4.0, 0.8));
                }
                deltas.push(t);
            }
            let merge_in_order = |order: &[usize]| -> StatsTable {
                let mut global = StatsTable::new();
                for &i in order {
                    global.merge(&deltas[i]);
                }
                global
            };
            let fwd: Vec<usize> = (0..n_ranks).collect();
            let mut shuffled = fwd.clone();
            rng.shuffle(&mut shuffled);
            let a = merge_in_order(&fwd);
            let b = merge_in_order(&shuffled);
            for (fid, sa) in a.iter() {
                let sb: &RunStats = b.get(fid).ok_or("missing fid")?;
                if sa.count() != sb.count() {
                    return Err("count order-dependent".into());
                }
                if (sa.mean() - sb.mean()).abs() > 1e-9 * (1.0 + sa.mean().abs()) {
                    return Err("mean order-dependent".into());
                }
                if (sa.m2() - sb.m2()).abs() > 1e-6 * (1.0 + sa.m2().abs()) {
                    return Err("m2 order-dependent".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------------
// VizSnapshot::merge algebra — the contract the aggregation tree leans on.

/// Exact fingerprint of a snapshot (integers verbatim, floats by bit
/// pattern). `merge` moves rank summaries, fresh steps and events between
/// snapshots without any float arithmetic, so order-independence must
/// hold *bitwise*, not just within tolerance. The `delta` flag is not
/// folded by `merge` (every partial in a publish round carries the same
/// value), so it stays out of the fingerprint.
fn viz_fingerprint(s: &VizSnapshot) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    write!(
        out,
        "ta:{} te:{} ft:{} pe:{};",
        s.total_anomalies, s.total_executions, s.functions_tracked, s.placement_epoch
    )
    .unwrap();
    for r in &s.ranks {
        let c = &r.step_counts;
        write!(
            out,
            "R{}:{}:{}:{}:{:x}:{:x}:{:x}:{:x};",
            r.app,
            r.rank,
            r.total_anomalies,
            c.count(),
            c.mean().to_bits(),
            c.m2().to_bits(),
            c.min().to_bits(),
            c.max().to_bits()
        )
        .unwrap();
    }
    for f in &s.fresh_steps {
        write!(
            out,
            "F{}:{}:{}:{}:{}:{}:{};",
            f.step, f.app, f.rank, f.n_executions, f.n_anomalies, f.ts_range.0, f.ts_range.1
        )
        .unwrap();
    }
    for e in &s.global_events {
        write!(out, "E{}:{}:{:x};", e.step, e.total_anomalies, e.score.to_bits()).unwrap();
    }
    for l in &s.shard_loads {
        write!(out, "S{l:?};").unwrap();
    }
    for n in &s.agg_nodes {
        write!(out, "N{n:?};").unwrap();
    }
    out
}

fn rand_run_stats(rng: &mut Rng) -> RunStats {
    let mut s = RunStats::new();
    for _ in 0..1 + rng.usize(6) {
        s.push(rng.lognormal(3.0, 1.0));
    }
    s
}

/// Generate `parts` key-disjoint partial snapshots — the shape `merge`
/// is defined over: in a publish round each rank summary comes from
/// exactly one aggregator partial, each shard load from one stat shard,
/// each tree-node counter from one node, and the aggregator plane flags
/// each global event's step exactly once. (With colliding keys `merge`
/// is first-writer-wins on events and stable-sort-ordered on ranks, so
/// order-independence only holds under this disjointness — which is why
/// the generator enforces it instead of sampling keys independently.)
fn rand_partials(rng: &mut Rng, parts: usize, size: usize) -> Vec<VizSnapshot> {
    let mut out: Vec<VizSnapshot> = (0..parts)
        .map(|_| VizSnapshot { delta: true, ..VizSnapshot::default() })
        .collect();
    for rank in 0..rng.usize(size) {
        let p = &mut out[rng.usize(parts)];
        p.ranks.push(RankSummary {
            app: rng.usize(3) as u32,
            rank: rank as u32,
            step_counts: rand_run_stats(rng),
            total_anomalies: rng.usize(50) as u64,
        });
    }
    for step in 0..rng.usize(size) {
        let p = &mut out[rng.usize(parts)];
        p.fresh_steps.push(StepStat {
            app: rng.usize(3) as u32,
            rank: rng.usize(64) as u32,
            step: step as u64,
            n_executions: 1 + rng.usize(1000) as u64,
            n_anomalies: rng.usize(10) as u64,
            ts_range: (step as u64 * 1_000, step as u64 * 1_000 + 999),
        });
    }
    for j in 0..rng.usize(4) {
        let p = &mut out[rng.usize(parts)];
        p.global_events.push(GlobalEvent {
            step: 1_000 + j as u64,
            total_anomalies: 10 + rng.usize(100) as u64,
            score: rng.range_f64(3.0, 9.0),
        });
    }
    for shard in 0..rng.usize(5) {
        let p = &mut out[rng.usize(parts)];
        p.shard_loads.push(ShardLoad {
            shard: shard as u32,
            syncs: rng.usize(1_000) as u64,
            merges: rng.usize(10_000) as u64,
            functions: rng.usize(200) as u64,
            slots: rng.usize(16) as u32,
            shed: rng.usize(5) as u64,
            queue_depth: rng.usize(1 << 16) as u64,
        });
    }
    for node in 0..rng.usize(8) {
        let p = &mut out[rng.usize(parts)];
        p.agg_nodes.push(AggNodeLoad {
            node: node as u32,
            depth: rng.usize(4) as u32,
            rank_lo: node as u32 * 8,
            rank_hi: node as u32 * 8 + 8,
            folds: rng.usize(10_000) as u64,
            pushed: rng.usize(1_000) as u64,
            shed: rng.usize(10) as u64,
        });
    }
    for p in &mut out {
        p.total_anomalies = rng.usize(1_000) as u64;
        p.total_executions = rng.usize(100_000) as u64;
        p.functions_tracked = rng.usize(100) as u64;
        p.placement_epoch = rng.usize(5) as u64;
    }
    out
}

fn merged(a: &VizSnapshot, b: &VizSnapshot) -> VizSnapshot {
    let mut m = a.clone();
    m.merge(b);
    m
}

#[test]
fn prop_viz_merge_is_commutative_and_associative() {
    check(
        "viz-merge-algebra",
        PropConfig { cases: 80, seed: 0xA661, max_size: 48 },
        |rng, size| {
            let parts = rand_partials(rng, 3, size.max(1));
            let (a, b, c) = (&parts[0], &parts[1], &parts[2]);
            // Commutativity: fold order of two partials is irrelevant.
            let ab = viz_fingerprint(&merged(a, b));
            let ba = viz_fingerprint(&merged(b, a));
            if ab != ba {
                return Err(format!("merge not commutative:\n  a∪b={ab}\n  b∪a={ba}"));
            }
            // Associativity: tree shape of the fold is irrelevant — the
            // aggregation tree folds (leaf∪leaf)∪leaf, the flat
            // aggregator folds left-to-right; both must agree.
            let ab_c = viz_fingerprint(&merged(&merged(a, b), c));
            let a_bc = viz_fingerprint(&merged(a, &merged(b, c)));
            if ab_c != a_bc {
                return Err(format!("merge not associative:\n  (a∪b)∪c={ab_c}\n  a∪(b∪c)={a_bc}"));
            }
            // Identity: an empty partial only canonicalizes ordering.
            let empty = VizSnapshot { delta: true, ..VizSnapshot::default() };
            let ae = viz_fingerprint(&merged(a, &empty));
            let ea = viz_fingerprint(&merged(&empty, a));
            if ae != ea {
                return Err(format!("empty partial not an identity:\n  a∪∅={ae}\n  ∅∪a={ea}"));
            }
            Ok(())
        },
    );
}
