//! The provDB service acceptance properties:
//!
//! 1. **Equivalence** — for any shard count in {1, 2, 4}, the networked
//!    provenance database answers every `ProvQuery` and call-stack query
//!    bit-identically to a local `ProvDb` fed the same record stream
//!    (retention disabled). The stream deliberately contains entry-time
//!    and score ties so the sequence tie-breaking is pinned, not just the
//!    primary sort keys.
//! 2. **Codec-independence** — a binary-logged store and a JSONL-logged
//!    store fed the same stream answer every extended `ProvQuery` with
//!    identical record sets, *after* a flush + restart recovery — and a
//!    JSONL data directory restarted under the binary format (the
//!    migration path) keeps answering identically.
//! 3. **End-to-end** — a full driver run with `provdb.addr` configured
//!    lands every kept record in the service, and the viz HTTP server
//!    serves `/api/provenance` and `/api/metadata` from it.

use chimbuko::config::Config;
use chimbuko::coordinator::{run, Mode, Workflow};
use chimbuko::provdb::{spawn_store, spawn_store_fmt, ProvClient, ProvDbTcpServer, Retention};
use chimbuko::provenance::{ProvDb, ProvQuery, ProvRecord, RecordFormat};
use chimbuko::util::rng::Rng;
use chimbuko::viz::{http, ProvSource, VizState};
use std::sync::{Arc, RwLock};

fn record(rng: &mut Rng, i: u64) -> ProvRecord {
    let app = (i % 2) as u32;
    let rank = rng.usize(5) as u32;
    let step = rng.usize(4) as u64;
    // Deliberate ties: entry times on a coarse grid, scores from a small
    // set — the sort tie-breaker must match the local index exactly.
    let entry = rng.range_u64(0, 20) * 1_000;
    let dur = rng.range_u64(10, 3_000);
    let score = [0.0, 1.5, 1.5, 6.5, 6.5, 9.0][rng.usize(6)];
    let label = if score >= 6.0 {
        if rng.chance(0.5) { "anomaly_high" } else { "anomaly_low" }
    } else {
        "normal"
    };
    ProvRecord {
        call_id: i,
        app,
        rank,
        thread: rng.usize(2) as u32,
        fid: rng.usize(6) as u32,
        func: format!("FN_{}", rng.usize(6)),
        step,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: dur / 2,
        depth: rng.usize(3) as u32,
        parent: if rng.chance(0.5) { Some(i.saturating_sub(1)) } else { None },
        n_children: rng.usize(3) as u32,
        n_messages: rng.usize(4) as u32,
        msg_bytes: rng.range_u64(0, 4096),
        label: label.to_string(),
        score,
    }
}

fn query_battery() -> Vec<ProvQuery> {
    let mut qs = vec![
        ProvQuery::default(),
        ProvQuery { anomalies_only: true, ..Default::default() },
        ProvQuery { order_by_score: true, ..Default::default() },
        ProvQuery { order_by_score: true, limit: Some(7), ..Default::default() },
        ProvQuery { limit: Some(13), ..Default::default() },
        ProvQuery { min_score: Some(6.0), ..Default::default() },
        ProvQuery { label: Some("anomaly_low".to_string()), ..Default::default() },
        ProvQuery { step_range: Some((1, 2)), ..Default::default() },
        ProvQuery { ts_range: Some((2_000, 9_000)), ..Default::default() },
        ProvQuery { rank: Some((0, 99)), ..Default::default() }, // missing rank
        ProvQuery { app: Some(0), ..Default::default() },
        ProvQuery { app: Some(1), anomalies_only: true, ..Default::default() },
        ProvQuery { fid: Some((1, 3)), order_by_score: true, ..Default::default() },
        ProvQuery {
            anomalies_only: true,
            order_by_score: true,
            min_score: Some(1.0),
            limit: Some(5),
            ..Default::default()
        },
    ];
    for app in 0..2u32 {
        for rank in 0..5u32 {
            qs.push(ProvQuery { rank: Some((app, rank)), ..Default::default() });
            qs.push(ProvQuery {
                rank: Some((app, rank)),
                step: Some(1),
                ..Default::default()
            });
            qs.push(ProvQuery {
                rank: Some((app, rank)),
                anomalies_only: true,
                order_by_score: true,
                ..Default::default()
            });
        }
        for fid in 0..6u32 {
            qs.push(ProvQuery { fid: Some((app, fid)), ..Default::default() });
        }
    }
    qs
}

#[test]
fn networked_provdb_is_bit_identical_to_local_for_any_shard_count() {
    let mut rng = Rng::new(0xD0C5);
    let records: Vec<ProvRecord> = (0..400u64).map(|i| record(&mut rng, i)).collect();

    // Shard sweep under the binary pipeline, plus one JSONL-logged +
    // JSONL-wire config: neither the store's log format nor the wire
    // encoding may change any answer.
    for (shards, format) in
        [(1usize, RecordFormat::Binary), (2, RecordFormat::Binary), (4, RecordFormat::Binary), (2, RecordFormat::Jsonl)]
    {
        let (store, handle) = spawn_store_fmt(None, shards, Retention::default(), format).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let addr = srv.addr().to_string();
        let mut client = ProvClient::connect_with(&addr, 32, format).unwrap();
        assert_eq!(client.shard_count(), shards);

        let mut local = ProvDb::in_memory();
        for r in &records {
            local.append_record(r.clone()).unwrap();
            client.append(r).unwrap();
        }
        client.flush().unwrap();

        for (qi, q) in query_battery().iter().enumerate() {
            let want: Vec<&ProvRecord> = local.query(q);
            let got = client.query(q).unwrap();
            assert_eq!(
                got.len(),
                want.len(),
                "shards={shards} query #{qi} {q:?}: {} vs {}",
                got.len(),
                want.len()
            );
            for (g, w) in got.iter().zip(want.iter()) {
                assert_eq!(g, *w, "shards={shards} query #{qi} {q:?} diverged");
            }
        }

        // Call-stack reconstruction for every (app, rank, step) — plus
        // holes that must come back empty.
        for app in 0..2u32 {
            for rank in 0..6u32 {
                for step in 0..5u64 {
                    let want: Vec<&ProvRecord> = local.call_stack(app, rank, step);
                    let got = client.call_stack(app, rank, step).unwrap();
                    assert_eq!(got.len(), want.len(), "stack ({app},{rank},{step})");
                    for (g, w) in got.iter().zip(want.iter()) {
                        assert_eq!(g, *w, "stack ({app},{rank},{step}) diverged");
                    }
                }
            }
        }

        // Aggregate counters agree with the local index; byte accounting
        // is format-dependent — the JSONL escape hatch matches the local
        // JSONL store byte-for-byte, the binary log is strictly smaller
        // per record.
        let stats = client.stats().unwrap();
        assert_eq!(stats.records, local.len() as u64, "shards={shards}");
        assert_eq!(stats.anomalies, local.anomaly_count(), "shards={shards}");
        match format {
            RecordFormat::Jsonl => {
                assert_eq!(stats.log_bytes, local.bytes_written(), "shards={shards}")
            }
            RecordFormat::Binary => assert!(
                stats.log_bytes < local.bytes_written(),
                "binary log {} must be smaller than JSONL {}",
                stats.log_bytes,
                local.bytes_written()
            ),
        }
        assert_eq!(stats.evicted, 0);
        assert_eq!(stats.log_errors, 0);

        drop(srv);
        handle.join();
    }
}

#[test]
fn binary_and_jsonl_logged_stores_answer_identically_after_restart() {
    let mut rng = Rng::new(0xC0DEC);
    let records: Vec<ProvRecord> = (0..300u64).map(|i| record(&mut rng, i)).collect();
    let dir_of = |tag: &str| {
        let d = std::env::temp_dir()
            .join(format!("chimbuko-provdb-codec-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d
    };
    let dir_bin = dir_of("bin");
    let dir_jsonl = dir_of("jsonl");

    // Phase 1: same stream into a binary-logged and a JSONL-logged
    // store (matching wire formats), then flush and shut down.
    for (dir, format) in
        [(&dir_bin, RecordFormat::Binary), (&dir_jsonl, RecordFormat::Jsonl)]
    {
        let (store, handle) =
            spawn_store_fmt(Some(dir.as_path()), 2, Retention::default(), format).unwrap();
        let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
        let mut client =
            ProvClient::connect_with(&srv.addr().to_string(), 16, format).unwrap();
        for r in &records {
            client.append(r).unwrap();
        }
        client.flush().unwrap();
        drop(srv);
        handle.join();
    }

    // Phase 2: restart both under the *binary* format — the JSONL dir
    // takes the segment reader's migration path — with different shard
    // counts, and compare every extended query record-for-record.
    let (store_a, ha) =
        spawn_store_fmt(Some(dir_bin.as_path()), 4, Retention::default(), RecordFormat::Binary)
            .unwrap();
    let (store_b, hb) = spawn_store_fmt(
        Some(dir_jsonl.as_path()),
        2,
        Retention::default(),
        RecordFormat::Binary,
    )
    .unwrap();
    for (qi, q) in query_battery().iter().enumerate() {
        let a = store_a.query(q);
        let b = store_b.query(q);
        assert_eq!(a.len(), b.len(), "query #{qi} {q:?}: {} vs {}", a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x, y, "query #{qi} {q:?} diverged across log formats");
        }
    }
    assert_eq!(store_a.query(&ProvQuery::default()).len(), records.len());

    // Post-migration appends land in segment files and keep both stores
    // identical after another flush + reload.
    let extra: Vec<ProvRecord> = (300..320u64).map(|i| record(&mut rng, i)).collect();
    store_a.ingest(extra.clone());
    store_b.ingest(extra);
    store_a.flush();
    store_b.flush();
    let a = store_a.query(&ProvQuery::default());
    let b = store_b.query(&ProvQuery::default());
    assert_eq!(a.len(), 320);
    assert_eq!(a, b);
    ha.join();
    hb.join();

    // Third generation: both dirs reload identically once more (the
    // JSONL dir now holds mixed .jsonl + .provseg files).
    let (store_a, ha) = spawn_store(Some(dir_bin.as_path()), 1, Retention::default()).unwrap();
    let (store_b, hb) = spawn_store(Some(dir_jsonl.as_path()), 4, Retention::default()).unwrap();
    for q in query_battery() {
        assert_eq!(store_a.query(&q), store_b.query(&q), "post-restart {q:?}");
    }
    ha.join();
    hb.join();
    std::fs::remove_dir_all(&dir_bin).ok();
    std::fs::remove_dir_all(&dir_jsonl).ok();
}

#[test]
fn flooded_provdb_sheds_while_behaved_clients_answer_identically() {
    // End-to-end backpressure on the provDB service: a connection that
    // floods requests and never drains replies is shed with `Busy`,
    // while a well-behaved client on the same server answers the whole
    // query battery identically to an uncontended server's client.
    use chimbuko::util::json::Json;
    use chimbuko::util::net::ReactorOpts;
    use chimbuko::util::wire::write_msg;
    use std::net::TcpStream;

    // META_GET kind byte, from the protocol doc in `provdb::net`.
    const KIND_META_GET: u8 = 6;

    let mut rng = Rng::new(0x0F10);
    let records: Vec<ProvRecord> = (0..200u64).map(|i| record(&mut rng, i)).collect();

    // Uncontended reference service, default reactor bounds.
    let (store_q, hq) = spawn_store(None, 2, Retention::default()).unwrap();
    let srv_q = ProvDbTcpServer::start("127.0.0.1:0", store_q.clone()).unwrap();

    // Flood target: tiny per-connection reply budget so the flood trips
    // admission control; huge server-wide budget so the flooded
    // connection sheds without starving the behaved one.
    let (store_f, hf) = spawn_store(None, 2, Retention::default()).unwrap();
    let srv_f = ProvDbTcpServer::start_with_opts(
        "127.0.0.1:0",
        store_f.clone(),
        ReactorOpts::new(1, 32 * 1024, 1 << 30),
    )
    .unwrap();

    // A ~256 KiB metadata blob makes every META_GET reply far exceed the
    // per-connection budget the moment the flooder stops draining.
    let blob = Json::obj(vec![("blob", Json::str("m".repeat(256 * 1024)))]);
    ProvClient::connect(&srv_f.addr().to_string())
        .unwrap()
        .set_metadata(&blob)
        .unwrap();

    let mut flood = TcpStream::connect(srv_f.addr().to_string()).unwrap();
    for _ in 0..200 {
        if write_msg(&mut flood, &[KIND_META_GET]).is_err() {
            break; // severed under the hard bound — acceptable
        }
    }
    let stats = srv_f.net_stats();
    let t0 = std::time::Instant::now();
    while stats.shed_count() == 0 && t0.elapsed() < std::time::Duration::from_secs(10) {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(stats.shed_count() > 0, "non-draining flood must be shed");

    // Behaved clients: same stream into both services, every query
    // answered identically despite the live flood.
    let mut cq = ProvClient::connect(&srv_q.addr().to_string()).unwrap();
    let mut cf = ProvClient::connect(&srv_f.addr().to_string()).unwrap();
    for r in &records {
        cq.append(r).unwrap();
        cf.append(r).unwrap();
    }
    cq.flush().unwrap();
    cf.flush().unwrap();
    for (qi, q) in query_battery().iter().enumerate() {
        assert_eq!(
            cq.query(q).unwrap(),
            cf.query(q).unwrap(),
            "query #{qi} {q:?} diverged under flood"
        );
    }

    // The stats reply carries the transport counters: shed on the
    // flooded server, none on the quiet one.
    let sf = cf.stats().unwrap();
    assert_eq!(sf.records, records.len() as u64);
    assert_eq!(sf.log_errors, 0);
    assert!(sf.shed > 0, "stats must surface the shed counter");
    let sq = cq.stats().unwrap();
    assert_eq!(sq.shed, 0, "well-behaved clients must never be shed");

    drop(flood);
    drop(srv_q);
    drop(srv_f);
    hq.join();
    hf.join();
}

#[test]
fn driver_run_with_provdb_serves_provenance_over_http() {
    // Spin up the service the way `chimbuko provdb-server` would…
    let (store, handle) = spawn_store(None, 2, Retention::default()).unwrap();
    let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
    let addr = srv.addr().to_string();

    // …run a workflow writing to it…
    let cfg = Config {
        ranks: 8,
        apps: 2,
        steps: 12,
        calls_per_step: 130,
        out_dir: String::new(),
        provdb_addr: addr.clone(),
        provdb_batch: 16,
        ..Config::default()
    };
    let w = Workflow::nwchem(&cfg);
    let report = run(&cfg, &w, Mode::TauChimbuko).unwrap();
    assert!(report.total_anomalies > 0);
    assert!(report.total_kept > 0);
    assert!(report.reduced_bytes > 0, "service log bytes must be collected");

    // Every kept record landed in the service.
    let stats = store.stats();
    assert_eq!(stats.records, report.total_kept);
    assert_eq!(stats.anomalies, report.total_anomalies);

    // …and serve the viz API from the service (the `serve --provdb` path).
    let mut state = VizState::new(w.registries.clone());
    state.db = ProvSource::remote(&addr).unwrap();
    let viz = http::VizServer::start("127.0.0.1:0", Arc::new(RwLock::new(state))).unwrap();

    let (code, body) =
        http::http_get(viz.addr(), "/api/provenance?anomalies=1&order=score&limit=10").unwrap();
    assert_eq!(code, 200);
    let j = chimbuko::util::json::parse(&body).unwrap();
    let n = j.get("count").unwrap().as_u64().unwrap();
    assert!(n > 0 && n <= 10, "count {n}");
    let recs = j.get("records").unwrap().as_arr().unwrap();
    assert_eq!(recs.len(), n as usize);
    assert!(recs
        .iter()
        .all(|r| r.get("label").unwrap().as_str() != Some("normal")));

    // Run metadata written by the driver comes back through the proxy.
    let (code, body) = http::http_get(viz.addr(), "/api/metadata").unwrap();
    assert_eq!(code, 200);
    let meta = chimbuko::util::json::parse(&body).unwrap();
    let run_id = meta.get("run_id").unwrap().as_str().unwrap();
    assert!(run_id.starts_with("run-seed"), "run_id {run_id}");
    assert!(meta.get("config").is_some());

    // A rank drill-down matches the service directly.
    let direct = store.call_stack(0, 0, 3);
    let (code, body) =
        http::http_get(viz.addr(), "/api/callstack?app=0&rank=0&step=3").unwrap();
    assert_eq!(code, 200);
    let j = chimbuko::util::json::parse(&body).unwrap();
    assert_eq!(
        j.get("executions").unwrap().as_arr().unwrap().len(),
        direct.len()
    );

    drop(viz);
    drop(srv);
    handle.join();
}

#[test]
fn retention_bounds_a_driver_run() {
    // Tight retention: the service stays bounded while the run's full
    // kept count keeps flowing through the log accounting.
    let (store, handle) =
        spawn_store(None, 2, Retention { max_records_per_rank: 10, ..Default::default() })
            .unwrap();
    let srv = ProvDbTcpServer::start("127.0.0.1:0", store.clone()).unwrap();
    let cfg = Config {
        ranks: 6,
        apps: 2,
        steps: 15,
        calls_per_step: 130,
        out_dir: String::new(),
        provdb_addr: srv.addr().to_string(),
        ..Config::default()
    };
    let w = Workflow::nwchem(&cfg);
    let report = run(&cfg, &w, Mode::TauChimbuko).unwrap();
    let stats = store.stats();
    assert_eq!(stats.records + stats.evicted, report.total_kept);
    assert!(stats.records <= 6 * 10, "retained {}", stats.records);
    if report.total_kept > 60 {
        assert!(stats.evicted > 0);
        assert!(stats.resident_bytes < stats.log_bytes);
    }
    drop(srv);
    handle.join();
}
