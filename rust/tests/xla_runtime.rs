//! Integration: the AOT artifacts load, execute, and the XLA detection
//! engine agrees with the pure-Rust reference engine on real workloads.
//!
//! Requires `make artifacts` (skips with a message otherwise).

use chimbuko::ad::{DetectEngine, DetectorConfig, ExecRecord, RustDetector};
use chimbuko::runtime::{AdBatchRequest, RuntimeService};
use chimbuko::stats::StatsTable;
use chimbuko::trace::gen::{toy_grammar, RankTracer};
use chimbuko::trace::nwchem::{self, InjectionConfig};
use chimbuko::trace::StepFrame;
use chimbuko::util::rng::Rng;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn service() -> Option<RuntimeService> {
    artifacts_dir().map(|d| RuntimeService::spawn(&d).expect("spawn runtime"))
}

fn rec(fid: u32, dur: u64, id: u64) -> ExecRecord {
    ExecRecord {
        call_id: id,
        app: 0,
        rank: 0,
        thread: 0,
        fid,
        step: 0,
        entry_ts: id * 10_000,
        exit_ts: id * 10_000 + dur,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        exclusive_us: dur,
    }
}

#[test]
fn artifact_smoke_executes() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let b = h.batch;
    let f = h.funcs;
    let mut exec_us = vec![0.0f32; b];
    let mut valid = vec![0.0f32; b];
    // 32 valid events of fid 0 around 1000µs, one outlier; warm priors
    // (n=1000, µ=1000, σ=25) so one outlier cannot hide by fattening σ.
    for i in 0..32 {
        exec_us[i] = 1000.0 + (i % 13) as f32;
        valid[i] = 1.0;
    }
    exec_us[31] = 1_000_000.0;
    let mut n = vec![0.0f32; f];
    let mut mu = vec![0.0f32; f];
    let mut m2 = vec![0.0f32; f];
    n[0] = 1000.0;
    mu[0] = 1000.0;
    m2[0] = 1000.0 * 25.0 * 25.0;
    let resp = h
        .ad_batch(AdBatchRequest {
            exec_us,
            fid: vec![0; b],
            valid,
            n,
            mu,
            m2,
            alpha: 6.0,
            min_samples: 10.0,
        })
        .unwrap();
    assert_eq!(resp.labels.len(), b);
    assert_eq!(resp.labels[31], 1, "outlier must label high");
    assert_eq!(resp.labels[..31].iter().filter(|&&l| l != 0).count(), 0);
    // Stats: fid 0 merged 1000 prior + 32 batch observations.
    assert_eq!(resp.n[0] as u64, 1032);
    assert!(resp.n[1..].iter().all(|&n| n == 0.0));
    // Padding slots stay normal.
    assert!(resp.labels[32..].iter().all(|&l| l == 0));
}

#[test]
fn ps_merge_artifact_matches_rust_pebay() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let f = h.funcs;
    let mut rng = Rng::new(3);
    // Two random stats tables, merged rust-side and xla-side.
    let mut a = StatsTable::new();
    let mut b = StatsTable::new();
    for _ in 0..500 {
        a.push(rng.usize(f) as u32, rng.lognormal(6.0, 0.4));
        b.push(rng.usize(f) as u32, rng.lognormal(6.5, 0.3));
    }
    let to_arrays = |t: &StatsTable| {
        let mut n = vec![0.0f32; f];
        let mut mu = vec![0.0f32; f];
        let mut m2 = vec![0.0f32; f];
        for (fid, st) in t.iter() {
            n[fid as usize] = st.count() as f32;
            mu[fid as usize] = st.mean() as f32;
            m2[fid as usize] = st.m2() as f32;
        }
        (n, mu, m2)
    };
    let (n, mu, m2) = h.ps_merge(to_arrays(&a), to_arrays(&b)).unwrap();
    let mut want = a.clone();
    want.merge(&b);
    for (fid, st) in want.iter() {
        let i = fid as usize;
        assert_eq!(n[i] as u64, st.count(), "count fid {fid}");
        let rel = |x: f32, y: f64| (x as f64 - y).abs() / (1.0 + y.abs());
        assert!(rel(mu[i], st.mean()) < 1e-4, "mean fid {fid}: {} vs {}", mu[i], st.mean());
        assert!(rel(m2[i], st.m2()) < 1e-2, "m2 fid {fid}: {} vs {}", m2[i], st.m2());
    }
}

#[test]
fn xla_engine_matches_rust_engine_on_synthetic_batches() {
    let Some(svc) = service() else { return };
    let h = svc.handle();
    let mut xla = chimbuko::runtime::XlaDetector::new(h, 6.0, 10);
    let mut rust = RustDetector::new(DetectorConfig { alpha: 6.0, min_samples: 10 });
    let mut rng = Rng::new(11);
    let mut id = 0u64;
    let mut total_anoms = 0u64;
    for _batch in 0..8 {
        // ≤ capacity batches so chunking semantics are identical.
        let records: Vec<ExecRecord> = (0..200)
            .map(|_| {
                let fid = rng.usize(8) as u32;
                let base = 500.0 + 300.0 * fid as f64;
                let dur = if rng.chance(0.01) {
                    (base * 40.0) as u64
                } else {
                    rng.normal_ms(base, base * 0.03).max(1.0) as u64
                };
                id += 1;
                rec(fid, dur, id)
            })
            .collect();
        let lx = DetectEngine::detect(&mut xla, records.clone());
        let lr = DetectEngine::detect(&mut rust, records);
        assert_eq!(lx.len(), lr.len());
        for (x, r) in lx.iter().zip(&lr) {
            assert_eq!(
                x.label, r.label,
                "label mismatch call {} (xla score {} rust score {})",
                x.rec.call_id, x.score, r.score
            );
            if x.label.is_anomaly() {
                total_anoms += 1;
                assert!((x.score - r.score).abs() / (1.0 + r.score) < 1e-3);
            }
        }
    }
    assert!(total_anoms > 0, "workload must contain anomalies");
    // Final statistics agree.
    for fid in 0..8u32 {
        let xs = xla.view().get(fid).unwrap();
        let rs = rust.view().get(fid).unwrap();
        assert_eq!(xs.count(), rs.count());
        assert!((xs.mean() - rs.mean()).abs() / rs.mean() < 1e-4);
    }
}

#[test]
fn xla_engine_handles_oversized_batches_by_chunking() {
    let Some(svc) = service() else { return };
    let cap = svc.handle().batch;
    let mut xla = chimbuko::runtime::XlaDetector::new(svc.handle(), 6.0, 10);
    let mut rng = Rng::new(13);
    let records: Vec<ExecRecord> = (0..(3 * cap + 17) as u64)
        .map(|i| rec(2, rng.normal_ms(900.0, 25.0).max(1.0) as u64, i))
        .collect();
    let labeled = DetectEngine::detect(&mut xla, records);
    assert_eq!(labeled.len(), 3 * cap + 17);
    let st = xla.view().get(2).unwrap();
    assert_eq!(st.count(), (3 * cap + 17) as u64);
    assert!((st.mean() - 900.0).abs() < 20.0);
}

#[test]
fn xla_engine_in_on_node_ad_on_nwchem_workload() {
    let Some(svc) = service() else { return };
    let inj = InjectionConfig {
        forces_delay_prob: 0.01,
        rank0_straggle_prob: 0.0,
        getxbl_tail_prob: 0.01,
    };
    let (g, reg) = nwchem::md_grammar(4, &inj);
    let mut tracer = RankTracer::new(g, 0, 1, 8, false, Rng::new(7));
    let mut ad = chimbuko::ad::OnNodeAd::new(
        0,
        1,
        5,
        Box::new(chimbuko::runtime::XlaDetector::new(svc.handle(), 6.0, 30)),
    );
    let mut execs = 0u64;
    let mut anoms = 0u64;
    let mut kept = 0u64;
    for _ in 0..60 {
        let frame: StepFrame = tracer.step();
        let res = ad.process_step(&frame);
        execs += res.n_executions;
        anoms += res.n_anomalies;
        kept += res.kept.len() as u64;
    }
    assert!(execs > 1000);
    assert!(anoms > 0, "injected anomalies must be detected");
    assert!(kept >= anoms);
    // Data reduction: kept must be a small fraction.
    assert!((kept as f64) < 0.2 * execs as f64, "kept {kept}/{execs}");
    // Sanity: the anomalous function names include injected targets.
    let _ = reg;
}

#[test]
fn toy_grammar_via_xla_detector_is_deterministic() {
    let Some(svc) = service() else { return };
    let run = |svc: &RuntimeService| {
        let (g, _) = toy_grammar();
        let mut tracer = RankTracer::new(g, 0, 0, 4, false, Rng::new(21));
        let mut ad = chimbuko::ad::OnNodeAd::new(
            0,
            0,
            3,
            Box::new(chimbuko::runtime::XlaDetector::new(svc.handle(), 6.0, 10)),
        );
        let mut sig = Vec::new();
        for _ in 0..20 {
            let res = ad.process_step(&tracer.step());
            sig.push((res.n_executions, res.n_anomalies, res.kept.len()));
        }
        sig
    };
    assert_eq!(run(&svc), run(&svc));
}
