//! Acceptance properties of the columnar v2 segment plane:
//!
//! 1. **Mixed-version migration** — a data directory accumulated across
//!    three store generations (JSONL partitions, then legacy v1 row
//!    segments, then rolling sealed v2 segments) recovers losslessly,
//!    compacts to v2 as partitions seal, and answers the full extended
//!    query battery *and* probe subscriptions bit-identically to a
//!    store that never sealed (pure v1 layout) fed the same stream —
//!    across flush, restart, and the in-place migration itself.
//! 2. **Torn-tail repair** — a sealed v2 segment that loses its footer
//!    (crash mid-rename tail tear) is sidelined to `*.provseg.corrupt`,
//!    its salvageable prefix rewritten as an appendable v1 row file, and
//!    the survivors keep answering identically; a segment gutted down to
//!    its file header loses exactly its own records and nothing else,
//!    stably across further restarts.

use chimbuko::probe::{InstalledProbe, Probe};
use chimbuko::provdb::{spawn_store, spawn_store_fmt, ProvStore, Retention};
use chimbuko::provenance::{codec, ProvQuery, ProvRecord, RecordFormat};
use chimbuko::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Record stream with deliberate entry-time and score ties (kept in
/// sync with `tests/provdb_service.rs`) so sequence tie-breaking is
/// pinned, not just the primary sort keys.
fn record(rng: &mut Rng, i: u64) -> ProvRecord {
    let app = (i % 2) as u32;
    let rank = rng.usize(5) as u32;
    let step = rng.usize(4) as u64;
    let entry = rng.range_u64(0, 20) * 1_000;
    let dur = rng.range_u64(10, 3_000);
    let score = [0.0, 1.5, 1.5, 6.5, 6.5, 9.0][rng.usize(6)];
    let label = if score >= 6.0 {
        if rng.chance(0.5) { "anomaly_high" } else { "anomaly_low" }
    } else {
        "normal"
    };
    ProvRecord {
        call_id: i,
        app,
        rank,
        thread: rng.usize(2) as u32,
        fid: rng.usize(6) as u32,
        func: format!("FN_{}", rng.usize(6)),
        step,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: dur / 2,
        depth: rng.usize(3) as u32,
        parent: if rng.chance(0.5) { Some(i.saturating_sub(1)) } else { None },
        n_children: rng.usize(3) as u32,
        n_messages: rng.usize(4) as u32,
        msg_bytes: rng.range_u64(0, 4096),
        label: label.to_string(),
        score,
    }
}

fn query_battery() -> Vec<ProvQuery> {
    let mut qs = vec![
        ProvQuery::default(),
        ProvQuery { anomalies_only: true, ..Default::default() },
        ProvQuery { order_by_score: true, ..Default::default() },
        ProvQuery { order_by_score: true, limit: Some(7), ..Default::default() },
        ProvQuery { limit: Some(13), ..Default::default() },
        ProvQuery { min_score: Some(6.0), ..Default::default() },
        ProvQuery { label: Some("anomaly_low".to_string()), ..Default::default() },
        ProvQuery { step_range: Some((1, 2)), ..Default::default() },
        ProvQuery { ts_range: Some((2_000, 9_000)), ..Default::default() },
        ProvQuery { rank: Some((0, 99)), ..Default::default() }, // missing rank
        ProvQuery { app: Some(0), ..Default::default() },
        ProvQuery { app: Some(1), anomalies_only: true, ..Default::default() },
        ProvQuery { fid: Some((1, 3)), order_by_score: true, ..Default::default() },
        ProvQuery {
            anomalies_only: true,
            order_by_score: true,
            min_score: Some(1.0),
            limit: Some(5),
            ..Default::default()
        },
    ];
    for app in 0..2u32 {
        for rank in 0..5u32 {
            qs.push(ProvQuery { rank: Some((app, rank)), ..Default::default() });
            qs.push(ProvQuery {
                rank: Some((app, rank)),
                step_range: Some((0, 1)),
                ..Default::default()
            });
            qs.push(ProvQuery {
                rank: Some((app, rank)),
                anomalies_only: true,
                order_by_score: true,
                ..Default::default()
            });
        }
        for fid in 0..6u32 {
            qs.push(ProvQuery { fid: Some((app, fid)), ..Default::default() });
        }
    }
    qs
}

/// Probe sources covering the predicate shapes the warm tier must
/// answer: anomaly-gated, zone-correlated (step window), and match-all.
const PROBES: [&str; 3] = [
    "probe hot: fn:*.*:exit / score >= 6.0 && anomaly / { capture(record); }",
    "probe steps: fn:*.*:exit / step >= 1 && step <= 2 /",
    "probe all: fn:*.*:exit",
];

/// Byte-compare the full query battery + every probe between two
/// stores: `query_encoded` and `probe_scan` replies must be identical
/// down to the encoded record bytes and their merge order.
fn assert_identical(tag: &str, a: &ProvStore, b: &ProvStore) {
    for (qi, q) in query_battery().iter().enumerate() {
        let x = a.query_encoded(q);
        let y = b.query_encoded(q);
        assert_eq!(x.len(), y.len(), "{tag}: query #{qi} {q:?}: {} vs {}", x.len(), y.len());
        assert_eq!(x, y, "{tag}: query #{qi} {q:?} diverged");
    }
    for src in PROBES {
        let probe = Arc::new(InstalledProbe::new(Probe::compile(src).unwrap()));
        assert_eq!(a.probe_scan(&probe), b.probe_scan(&probe), "{tag}: probe {src} diverged");
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d =
        std::env::temp_dir().join(format!("chimbuko-provseg-{tag}-{}", std::process::id()));
    std::fs::remove_dir_all(&d).ok();
    d
}

fn set_len(path: &Path, len: u64) {
    std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .unwrap()
        .set_len(len)
        .unwrap();
}

#[test]
fn mixed_version_dirs_migrate_and_match_a_v1_store_bit_identically() {
    let mut rng = Rng::new(0x5E62);
    let records: Vec<ProvRecord> = (0..360u64).map(|i| record(&mut rng, i)).collect();
    let ref_dir = tmpdir("segref");
    let v2_dir = tmpdir("segv2");

    // Generation 1: JSONL-format stores write classic *.jsonl partitions.
    for dir in [&ref_dir, &v2_dir] {
        let (store, handle) =
            spawn_store_fmt(Some(dir.as_path()), 2, Retention::default(), RecordFormat::Jsonl)
                .unwrap();
        store.ingest(records[..120].to_vec());
        store.flush();
        handle.join();
    }

    // Generation 2: binary stores replay the JSONL in place (no rewrite)
    // and append legacy v1 row files next to it.
    for dir in [&ref_dir, &v2_dir] {
        let (store, handle) =
            spawn_store_fmt(Some(dir.as_path()), 2, Retention::default(), RecordFormat::Binary)
                .unwrap();
        store.ingest(records[120..240].to_vec());
        store.flush();
        handle.join();
        let names: Vec<String> = std::fs::read_dir(dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_str().unwrap().to_string())
            .collect();
        assert!(
            names.iter().any(|n| n.starts_with("prov_") && n.ends_with(".jsonl")),
            "gen-1 JSONL must stay in place: {names:?}"
        );
        assert!(
            names.iter().any(|n| n.starts_with("prov_")
                && n.ends_with(".provseg")
                && !n.contains("_seg")),
            "gen-2 legacy v1 log missing: {names:?}"
        );
    }

    // Generation 3: both dirs restart under the binary format; the
    // reference keeps segment rolling disabled (pure v1 layout, knob 0 =
    // never seal) while the other seals every 8 hot records into rolling
    // columnar v2 segments — compacting the mixed directory as it goes.
    let (ref_store, rh) = spawn_store_fmt(
        Some(ref_dir.as_path()),
        4,
        Retention::default().with_segment_knob(0),
        RecordFormat::Binary,
    )
    .unwrap();
    let (v2_store, vh) = spawn_store_fmt(
        Some(v2_dir.as_path()),
        4,
        Retention::default().with_segment_knob(8),
        RecordFormat::Binary,
    )
    .unwrap();
    ref_store.ingest(records[240..].to_vec());
    v2_store.ingest(records[240..].to_vec());
    ref_store.flush();
    v2_store.flush();

    let rs = ref_store.stats();
    let vs = v2_store.stats();
    assert_eq!(rs.records, 360);
    assert_eq!(vs.records, 360);
    assert_eq!(rs.segments_total, 0, "knob 0 must never seal");
    assert!(vs.segments_total > 0, "partitions past the bound must have sealed");
    assert_eq!(vs.zone_map_bytes, vs.segments_total * codec::SEG2_FOOTER_LEN as u64);
    assert_identical("gen3", &v2_store, &ref_store);

    // A query no zone can admit is pruned from *every* warm segment
    // without decoding a record.
    let before = v2_store.stats();
    let none = v2_store.query(&ProvQuery { min_score: Some(100.0), ..Default::default() });
    assert!(none.is_empty());
    let after = v2_store.stats();
    assert_eq!(after.segments_skipped - before.segments_skipped, after.segments_total);

    rh.join();
    vh.join();

    // The sealed directory holds only rolling `_seg<K>` files now: the
    // JSONL and legacy v1 generations were superseded by seals.
    let mut sealed = 0u64;
    for entry in std::fs::read_dir(&v2_dir).unwrap() {
        let path = entry.unwrap().path();
        let name = path.file_name().unwrap().to_str().unwrap().to_string();
        if !name.starts_with("prov_") {
            continue;
        }
        assert!(!name.ends_with(".jsonl"), "JSONL survived compaction: {name}");
        assert!(name.contains("_seg"), "legacy v1 file survived compaction: {name}");
        if codec::read_seg2_footer_file(&path).unwrap().is_some() {
            sealed += 1;
        }
    }
    assert!(sealed > 0, "no sealed v2 segment on disk after compaction");

    // Generation 4: restart both again (fresh shard counts). Warm
    // segments are adopted by footer alone and the battery still
    // byte-matches the never-sealed reference.
    let (ref_store, rh) = spawn_store(Some(ref_dir.as_path()), 1, Retention::default()).unwrap();
    let (v2_store, vh) = spawn_store(
        Some(v2_dir.as_path()),
        2,
        Retention::default().with_segment_knob(8),
    )
    .unwrap();
    let vs = v2_store.stats();
    assert_eq!(vs.records, 360);
    assert_eq!(vs.segments_total, sealed, "every sealed file must be adopted warm");
    assert_identical("gen4", &v2_store, &ref_store);
    rh.join();
    vh.join();
    std::fs::remove_dir_all(&ref_dir).ok();
    std::fs::remove_dir_all(&v2_dir).ok();
}

/// Deterministic single-partition stream: segment K (10 records under
/// knob 10) owns exactly step K and the entry window [10K, 10K+9] ms,
/// so zone maps carve disjoint ranges and salvage sets are exact.
fn fixed_rec(i: u64) -> ProvRecord {
    let score = (i % 7) as f64 * 1.5;
    ProvRecord {
        call_id: i,
        app: 0,
        rank: 0,
        thread: 0,
        fid: (i % 4) as u32,
        func: format!("FN_{}", i % 4),
        step: i / 10,
        entry_us: i * 1_000,
        exit_us: i * 1_000 + 40,
        inclusive_us: 40,
        exclusive_us: 20,
        depth: 0,
        parent: None,
        n_children: 0,
        n_messages: 0,
        msg_bytes: 0,
        label: if score >= 6.0 { "anomaly_high".to_string() } else { "normal".to_string() },
        score,
    }
}

#[test]
fn torn_v2_tails_are_salvaged_sidelined_and_resealed() {
    let records: Vec<ProvRecord> = (0..30u64).map(fixed_rec).collect();
    let dir = tmpdir("torn");
    let seg = |k: u32| dir.join(format!("prov_app0_rank0_seg{k:04}.provseg"));

    // Seed: three sealed segments, empty hot tier.
    let (store, handle) =
        spawn_store(Some(dir.as_path()), 1, Retention::default().with_segment_knob(10)).unwrap();
    store.ingest(records.clone());
    store.flush();
    assert_eq!(store.stats().segments_total, 3);
    handle.join();
    for k in 0..3u32 {
        assert!(codec::read_seg2_footer_file(&seg(k)).unwrap().is_some(), "seg{k} not sealed");
    }

    // Damage A: cut 5 bytes off seg2's tail — the footer dies, the
    // packed body survives. Recovery sidelines the damaged file,
    // salvages every record into the hot tier, and answers the battery
    // identically to an undamaged all-resident store.
    let len = std::fs::metadata(seg(2)).unwrap().len();
    set_len(&seg(2), len - 5);
    let (store, handle) =
        spawn_store(Some(dir.as_path()), 1, Retention::default().with_segment_knob(10)).unwrap();
    let (reference, ref_handle) = spawn_store(None, 1, Retention::default()).unwrap();
    reference.ingest(records.clone());
    reference.flush();
    let stats = store.stats();
    assert_eq!(stats.records, 30, "torn footer with intact body salvages everything");
    assert_eq!(stats.segments_total, 2, "the salvaged records re-home as hot data");
    assert!(
        seg(2).with_extension("provseg.corrupt").exists(),
        "damaged segment must be sidelined for offline salvage"
    );
    assert_identical("torn-footer", &store, &reference);

    // Zone maps still prune around the damage: a step-0 window decodes
    // seg0, skips seg1 by zone alone, and scans the salvaged hot rows.
    let before = store.stats();
    let hits = store.query(&ProvQuery { step_range: Some((0, 0)), ..Default::default() });
    assert_eq!(hits.len(), 10);
    assert!(hits.iter().all(|r| r.step == 0));
    let after = store.stats();
    assert_eq!(after.segments_skipped - before.segments_skipped, 1);
    handle.join();
    ref_handle.join();

    // The shutdown flush resealed the salvaged rows (hot == knob) back
    // into a sealed v2 segment at the same rolling index.
    let footer = codec::read_seg2_footer_file(&seg(2)).unwrap().expect("seg2 resealed");
    assert_eq!(footer.n_records, 10);

    // Damage B: gut a sealed segment down to its file header — nothing
    // salvageable. Exactly that segment's records are lost; both
    // neighbours keep answering identically to a reference holding the
    // survivors.
    set_len(&seg(1), 10);
    let (store, handle) =
        spawn_store(Some(dir.as_path()), 1, Retention::default().with_segment_knob(10)).unwrap();
    let survivors: Vec<ProvRecord> =
        records[..10].iter().chain(&records[20..]).cloned().collect();
    let (reference, ref_handle) = spawn_store(None, 1, Retention::default()).unwrap();
    reference.ingest(survivors);
    reference.flush();
    assert_eq!(store.stats().records, 20);
    assert_eq!(store.stats().segments_total, 2);
    assert!(seg(1).with_extension("provseg.corrupt").exists());
    assert_identical("gutted-body", &store, &reference);
    handle.join();
    ref_handle.join();

    // The repair is stable: another restart loses nothing further.
    let (store, handle) =
        spawn_store(Some(dir.as_path()), 1, Retention::default().with_segment_knob(10)).unwrap();
    assert_eq!(store.stats().records, 20);
    assert_eq!(store.stats().segments_total, 2);
    handle.join();
    std::fs::remove_dir_all(&dir).ok();
}

/// Crash-during-repair idempotence (chaos-plane property): segment
/// repair is sideline-copy → tmp-write → atomic rename, so a process
/// killed at *any* point inside it leaves a directory that the next
/// recovery repairs to the exact same store as one uninterrupted
/// recovery of the original damage. Checked for both crash states a
/// kill can produce — after the sideline copy but before the rewrite
/// (stale `.tmp` on disk), and after a complete repair pass — against
/// a single clean recovery, over the full query battery.
#[test]
fn crash_during_segment_repair_recovers_idempotently() {
    let records: Vec<ProvRecord> = (0..30u64).map(fixed_rec).collect();
    let dir = tmpdir("crashrec");
    let seg = |d: &Path, k: u32| d.join(format!("prov_app0_rank0_seg{k:04}.provseg"));
    let knob10 = || Retention::default().with_segment_knob(10);

    // Seed three sealed segments, then tear seg1's footer off: the
    // packed body survives, so repair must salvage all 10 records.
    let (store, handle) = spawn_store(Some(dir.as_path()), 1, knob10()).unwrap();
    store.ingest(records.clone());
    store.flush();
    assert_eq!(store.stats().segments_total, 3);
    handle.join();
    let len = std::fs::metadata(seg(&dir, 1)).unwrap().len();
    set_len(&seg(&dir, 1), len - 5);

    // Snapshot the damaged directory before any recovery touches it.
    let mid_a = tmpdir("crashrec-a");
    let mid_b = tmpdir("crashrec-b");
    for d in [&mid_a, &mid_b] {
        std::fs::create_dir_all(d).unwrap();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let p = entry.unwrap().path();
            std::fs::copy(&p, d.join(p.file_name().unwrap())).unwrap();
        }
    }

    // Crash state A: killed between the sideline copy and the atomic
    // prefix rewrite — the sideline already exists, the live path still
    // holds the damaged v2 bytes, and a half-written `.tmp` litters the
    // directory (recovery must overwrite both, and must not scan them).
    std::fs::copy(seg(&mid_a, 1), seg(&mid_a, 1).with_extension("provseg.corrupt")).unwrap();
    std::fs::write(seg(&mid_a, 1).with_extension("tmp"), b"half-written junk").unwrap();

    // Crash state B: a first repair pass ran to completion on disk and
    // the process died right after — the second recovery below starts
    // from the already-repaired layout (salvaged prefix living as a v1
    // row file at the damaged index). Rolling is disabled for this pass
    // (knob 0) so its shutdown does not also reseal the salvage: repair
    // itself is knob-independent, and resealing would legitimately
    // renumber arrival order, which is not the property under test.
    let (b1, h1) =
        spawn_store(Some(mid_b.as_path()), 1, Retention::default().with_segment_knob(0)).unwrap();
    assert_eq!(b1.stats().records, 30);
    h1.join();

    // Recover all three — the pristine damage once, and each crash
    // state — and require bit-identical answers everywhere.
    let (once, oh) = spawn_store(Some(dir.as_path()), 1, knob10()).unwrap();
    let (from_a, ah) = spawn_store(Some(mid_a.as_path()), 1, knob10()).unwrap();
    let (from_b, bh) = spawn_store(Some(mid_b.as_path()), 1, knob10()).unwrap();
    for (tag, s, d) in
        [("clean", &once, &dir), ("mid-repair", &from_a, &mid_a), ("post-repair", &from_b, &mid_b)]
    {
        assert_eq!(s.stats().records, 30, "{tag}: salvage must lose nothing");
        assert!(
            seg(d, 1).with_extension("provseg.corrupt").exists(),
            "{tag}: sideline must survive every repair pass"
        );
    }
    assert_identical("crash-mid-repair", &from_a, &once);
    assert_identical("crash-post-repair", &from_b, &once);
    // The interrupted rewrite's stale tmp was redone and consumed by the
    // rename, not adopted as data.
    assert!(!seg(&mid_a, 1).with_extension("tmp").exists());
    oh.join();
    ah.join();
    bh.join();
    for d in [&dir, &mid_a, &mid_b] {
        std::fs::remove_dir_all(d).ok();
    }
}
