//! End-to-end integration: the full coordinator pipeline (SST → AD → PS →
//! provenance → viz/HTTP), cross-mode consistency, failure injection, and
//! offline replay. Uses the Rust detector backend so it runs without
//! artifacts; the XLA-path equivalents live in `xla_runtime.rs`.

use chimbuko::config::{Config, TraceEngine};
use chimbuko::coordinator::{run, Mode, RunReport, Workflow};
use chimbuko::provenance::{ProvDb, ProvQuery};
use chimbuko::trace::filter::filter_frames;
use chimbuko::trace::nwchem::{self, InjectionConfig};
use chimbuko::trace::RankTracer;
use chimbuko::util::rng::Rng;
use chimbuko::viz::{http, VizState};
use std::sync::{Arc, RwLock};

fn cfg(ranks: usize, steps: usize) -> Config {
    Config {
        ranks,
        apps: 2,
        steps,
        calls_per_step: 130,
        out_dir: String::new(),
        ..Config::default()
    }
}

#[test]
fn full_pipeline_then_viz_over_http() {
    let dir = std::env::temp_dir().join(format!("chimbuko-pipe-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut c = cfg(12, 25);
    c.out_dir = dir.to_str().unwrap().to_string();
    let w = Workflow::nwchem(&c);
    let report = run(&c, &w, Mode::TauChimbuko).unwrap();
    assert!(report.total_anomalies > 0);

    let db = ProvDb::load(&dir).unwrap();
    let state = VizState::from_run(
        &report.snapshots,
        report.snapshot.clone(),
        db,
        w.registries.clone(),
    );
    // The drill-down path the paper describes, over real HTTP.
    let state = Arc::new(RwLock::new(state));
    let mut srv = http::VizServer::start("127.0.0.1:0", state.clone()).unwrap();
    let (code, body) = http::http_get(srv.addr(), "/api/dashboard?stat=total&n=3").unwrap();
    assert_eq!(code, 200);
    let j = chimbuko::util::json::parse(&body).unwrap();
    let top = j.get("top").unwrap().as_arr().unwrap();
    assert!(!top.is_empty());
    let rank = top[0].get("rank").unwrap().as_u64().unwrap();
    let app = top[0].get("app").unwrap().as_u64().unwrap();

    let (code, body) =
        http::http_get(srv.addr(), &format!("/api/timeline?app={app}&rank={rank}")).unwrap();
    assert_eq!(code, 200);
    let j = chimbuko::util::json::parse(&body).unwrap();
    let series = j.get("series").unwrap().as_arr().unwrap();
    assert!(!series.is_empty(), "top rank must have timeline points");

    // Find an anomalous step and fetch its call stack.
    let anomalous_step = series
        .iter()
        .find(|p| p.get("n_anomalies").unwrap().as_u64().unwrap() > 0)
        .map(|p| p.get("step").unwrap().as_u64().unwrap());
    if let Some(step) = anomalous_step {
        let (code, body) = http::http_get(
            srv.addr(),
            &format!("/api/callstack?app={app}&rank={rank}&step={step}"),
        )
        .unwrap();
        assert_eq!(code, 200);
        let j = chimbuko::util::json::parse(&body).unwrap();
        assert!(!j.get("executions").unwrap().as_arr().unwrap().is_empty());
    }
    srv.stop();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn anomaly_counts_consistent_across_ps_viz_prov() {
    let dir = std::env::temp_dir().join(format!("chimbuko-cons-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut c = cfg(8, 20);
    c.out_dir = dir.to_str().unwrap().to_string();
    let w = Workflow::nwchem(&c);
    let report = run(&c, &w, Mode::TauChimbuko).unwrap();

    // PS totals == sum over rank summaries == provenance anomaly count.
    let ps_total = report.snapshot.total_anomalies;
    let rank_sum: u64 = report.snapshot.ranks.iter().map(|r| r.total_anomalies).sum();
    assert_eq!(ps_total, rank_sum);
    assert_eq!(ps_total, report.total_anomalies);
    let db = ProvDb::load(&dir).unwrap();
    assert_eq!(db.anomaly_count(), ps_total);
    // Timeline points sum to the same number.
    let timeline_sum: u64 = report
        .snapshots
        .iter()
        .flat_map(|s| s.fresh_steps.iter())
        .map(|st| st.n_anomalies)
        .sum();
    assert_eq!(timeline_sum, ps_total);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn alpha_sweep_is_monotone_end_to_end() {
    let mut counts = Vec::new();
    for alpha in [3.0, 6.0, 12.0] {
        let mut c = cfg(6, 15);
        c.alpha = alpha;
        let w = Workflow::nwchem(&c);
        let r = run(&c, &w, Mode::TauChimbuko).unwrap();
        counts.push(r.total_anomalies);
    }
    assert!(counts[0] >= counts[1], "alpha 3 {} < alpha 6 {}", counts[0], counts[1]);
    assert!(counts[1] >= counts[2], "alpha 6 {} < alpha 12 {}", counts[1], counts[2]);
    assert!(counts[0] > counts[2], "sweep should separate extremes");
}

#[test]
fn clean_workload_produces_near_zero_anomalies() {
    let mut c = cfg(6, 15);
    c.seed = 5;
    let w = Workflow::nwchem_with_injection(&c, InjectionConfig::none());
    let r = run(&c, &w, Mode::TauChimbuko).unwrap();
    // 6σ on clean lognormal workloads: a tiny false-positive residue is
    // acceptable (heavy-ish tails), but it must be ≪ injected runs.
    let rate = r.total_anomalies as f64 / r.total_execs.max(1) as f64;
    assert!(rate < 0.002, "false positive rate {rate}");
}

#[test]
fn unfiltered_stream_filters_to_filtered_stream() {
    // filter(gen(unfiltered)) ≡ gen(filtered) modulo timestamps: same
    // function multiset per step.
    let inj = InjectionConfig::none();
    let (g, reg) = nwchem::md_grammar(3, &inj);
    let mut unf = RankTracer::new(g.clone(), 0, 1, 4, true, Rng::new(9));
    let mut fil = RankTracer::new(g, 0, 1, 4, false, Rng::new(9));
    let frames_u: Vec<_> = (0..5).map(|_| unf.step()).collect();
    let frames_f: Vec<_> = (0..5).map(|_| fil.step()).collect();
    let filtered = filter_frames(&frames_u, &reg);
    for (a, b) in filtered.iter().zip(&frames_f) {
        let fids = |fr: &chimbuko::trace::StepFrame| {
            let mut v: Vec<u32> = fr
                .events
                .iter()
                .filter_map(|e| match e {
                    chimbuko::trace::Event::Func(f) => Some(f.fid),
                    _ => None,
                })
                .collect();
            v.sort();
            v
        };
        assert_eq!(fids(a), fids(b));
    }
}

#[test]
fn bp_and_sst_modes_agree_on_workload() {
    let c = cfg(6, 10);
    let w = Workflow::nwchem(&c);
    let tau = run(&c, &w, Mode::Tau).unwrap();
    let chi = run(&c, &w, Mode::TauChimbuko).unwrap();
    assert_eq!(tau.total_events, chi.total_events);
    // Chimbuko analysed every completed execution: function events are
    // ENTRY+EXIT pairs, so executions ≈ func_events / 2 (all calls close
    // within the run).
    assert!(chi.total_execs > 0);
}

#[test]
fn replay_equals_original_index() {
    let dir = std::env::temp_dir().join(format!("chimbuko-replay-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let mut c = cfg(8, 20);
    c.out_dir = dir.to_str().unwrap().to_string();
    let w = Workflow::nwchem(&c);
    let r = run(&c, &w, Mode::TauChimbuko).unwrap();

    let db = ProvDb::load(&dir).unwrap();
    assert_eq!(db.len() as u64, r.total_kept);
    // Query index integrity after reload: every anomaly is reachable via
    // its (rank, step) call-stack query.
    let anoms = db.query(&ProvQuery { anomalies_only: true, ..Default::default() });
    for a in anoms.iter().take(20) {
        let frame = db.call_stack(a.app, a.rank, a.step);
        assert!(
            frame.iter().any(|r| r.call_id == a.call_id),
            "anomaly {} missing from its frame query",
            a.call_id
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn backpressure_bounds_memory() {
    // A tiny SST queue forces writer waits but the run still completes
    // with identical analysis results.
    let mut c1 = cfg(4, 15);
    c1.sst_queue_depth = 1;
    let mut c2 = cfg(4, 15);
    c2.sst_queue_depth = 64;
    let w1 = Workflow::nwchem(&c1);
    let w2 = Workflow::nwchem(&c2);
    let r1: RunReport = run(&c1, &w1, Mode::TauChimbuko).unwrap();
    let r2: RunReport = run(&c2, &w2, Mode::TauChimbuko).unwrap();
    assert_eq!(r1.total_execs, r2.total_execs);
    assert_eq!(r1.total_anomalies, r2.total_anomalies);
}

#[test]
fn hbos_algorithm_end_to_end() {
    use chimbuko::config::AdAlgorithm;
    let mut c = cfg(8, 25);
    c.algorithm = AdAlgorithm::Hbos;
    let w = Workflow::nwchem(&c);
    let r = run(&c, &w, Mode::TauChimbuko).unwrap();
    assert!(r.total_execs > 1000);
    // HBOS must catch the injected far-tail anomalies too.
    assert!(r.total_anomalies > 0, "HBOS found no anomalies");
    // And stay selective.
    let rate = r.total_anomalies as f64 / r.total_execs as f64;
    assert!(rate < 0.05, "HBOS anomaly rate {rate}");
}

#[test]
fn engine_config_is_respected() {
    // TraceEngine::Bp in the config maps to Mode::Tau byte accounting.
    let mut c = cfg(4, 8);
    c.engine = TraceEngine::Bp;
    let w = Workflow::nwchem(&c);
    let r = run(&c, &w, Mode::Tau).unwrap();
    assert!(r.bp_bytes > 0);
}

#[test]
fn single_rank_workflow_works() {
    let mut c = cfg(1, 10);
    c.apps = 1;
    let w = Workflow::nwchem(&c);
    let r = run(&c, &w, Mode::TauChimbuko).unwrap();
    assert!(r.total_execs > 0);
    assert_eq!(r.snapshot.ranks.len(), 1);
}

#[test]
fn large_rank_count_smoke() {
    // More simulated ranks than cores: worker-pool multiplexing path.
    let c = cfg(256, 3);
    let w = Workflow::nwchem(&c);
    let r = run(&c, &w, Mode::TauChimbuko).unwrap();
    assert_eq!(r.snapshot.ranks.len(), 256);
    assert!(r.total_execs > 10_000);
}
