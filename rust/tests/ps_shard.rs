//! Sharded-vs-single parameter-server equivalence: for random delta/report
//! streams, a [`ShardedPs`](chimbuko::ps::shard) constellation with
//! N ∈ {1, 2, 4, 7} shards must produce bit-identical global `RunStats`,
//! anomaly totals, and global-event sets as the single-threaded
//! [`ParameterServer`] reference — Pébay merges are commutative, so the
//! hash routing must be invisible in the results.

use chimbuko::ps::{self, ParameterServer, PsRequest, StepStat};
use chimbuko::stats::StatsTable;
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;
use std::sync::mpsc::channel;

/// One step of the generated workload: every rank reports, then syncs.
struct StepOps {
    step: u64,
    /// Per-rank (report, delta) pairs, rank-ordered.
    per_rank: Vec<(StepStat, StatsTable)>,
}

/// Deterministic workload: `quiet` steps of mostly-zero anomaly counts
/// followed by one bursty step (so global-event detection has history to
/// trigger against), with random per-rank stat deltas that cover both the
/// dense (fid < 256) and spill (fid ≥ 256) paths of the stats table.
fn gen_workload(rng: &mut Rng, ranks: usize, quiet_steps: usize, delta_len: usize) -> Vec<StepOps> {
    let mut steps = Vec::new();
    for step in 0..=(quiet_steps as u64) {
        let burst = step == quiet_steps as u64;
        let mut per_rank = Vec::new();
        for rank in 0..ranks as u32 {
            let anoms = if burst {
                4 + rng.usize(4) as u64
            } else {
                u64::from(rank == 0 && step % 3 == 0)
            };
            let report = StepStat {
                app: 0,
                rank,
                step,
                n_executions: 50 + rng.usize(50) as u64,
                n_anomalies: anoms,
                ts_range: (step * 1000, step * 1000 + 999),
            };
            let mut delta = StatsTable::new();
            for _ in 0..delta_len.max(1) {
                let fid = if rng.chance(0.1) {
                    300 + rng.usize(8) as u32 // spill path
                } else {
                    rng.usize(24) as u32 // dense path
                };
                delta.push(fid, rng.lognormal(5.0, 1.0));
            }
            per_rank.push((report, delta));
        }
        steps.push(StepOps { step, per_rank });
    }
    steps
}

/// Drive the single-threaded reference; returns (server, per-sync replies).
fn drive_reference(
    workload: &[StepOps],
    ranks: usize,
) -> (ParameterServer, Vec<Vec<(u32, chimbuko::stats::RunStats)>>) {
    let mut ps = ParameterServer::new(None, usize::MAX >> 1, ranks);
    let mut replies = Vec::new();
    for ops in workload {
        for (report, delta) in &ops.per_rank {
            ps.handle(PsRequest::Report(report.clone()));
            let (rtx, rrx) = channel();
            ps.handle(PsRequest::Sync {
                app: report.app,
                rank: report.rank,
                delta: delta.iter().map(|(f, s)| (f, *s)).collect(),
                reply: rtx,
            });
            replies.push(rrx.recv().unwrap().global);
        }
    }
    (ps, replies)
}

#[test]
fn sharded_equivalence_property() {
    check(
        "sharded-vs-single-ps",
        PropConfig { cases: 12, seed: 0x5AAD, max_size: 24 },
        |rng, size| {
            let ranks = 2 + rng.usize(4);
            let workload = gen_workload(rng, ranks, 8 + rng.usize(4), size);
            let (reference, ref_replies) = drive_reference(&workload, ranks);

            for n_shards in [1usize, 2, 4, 7] {
                let (client, handle) = ps::spawn(n_shards, None, usize::MAX >> 1, ranks);
                let mut reply_idx = 0usize;
                let mut delivered_events = Vec::new();
                for ops in &workload {
                    for (report, delta) in &ops.per_rank {
                        client.report(report.clone());
                        let (global, events) = client.sync(report.app, report.rank, delta);
                        delivered_events.extend(events);
                        // Per-sync reply must match the reference
                        // bit-for-bit (same merge sequence per function).
                        let want = &ref_replies[reply_idx];
                        reply_idx += 1;
                        if global.len() != want.len() {
                            return Err(format!(
                                "{n_shards} shards: reply size {} vs {} at sync {}",
                                global.len(),
                                want.len(),
                                reply_idx
                            ));
                        }
                        for (fid, st) in want {
                            if global.get(*fid) != Some(st) {
                                return Err(format!(
                                    "{n_shards} shards: fid {fid} reply diverged at sync {reply_idx} (step {})",
                                    ops.step
                                ));
                            }
                        }
                    }
                }
                client.shutdown();
                let fin = handle.join();
                // Global stats: bit-identical, every key present.
                if fin.global_len() != reference.global_len() {
                    return Err(format!(
                        "{n_shards} shards: {} global functions vs {}",
                        fin.global_len(),
                        reference.global_len()
                    ));
                }
                for (key, st) in reference.global_iter() {
                    if fin.global.get(&key) != Some(st) {
                        return Err(format!("{n_shards} shards: global stats diverged for {key:?}"));
                    }
                }
                // Anomaly totals and timeline.
                let want_snap = reference.snapshot();
                if fin.snapshot.total_anomalies != want_snap.total_anomalies
                    || fin.snapshot.total_executions != want_snap.total_executions
                {
                    return Err(format!("{n_shards} shards: totals diverged"));
                }
                if fin.snapshot.ranks.len() != want_snap.ranks.len() {
                    return Err(format!("{n_shards} shards: rank summaries diverged"));
                }
                if fin.snapshot.functions_tracked != want_snap.functions_tracked {
                    return Err(format!("{n_shards} shards: functions_tracked diverged"));
                }
                // Global-event sets: same events flagged, all delivered.
                if fin.global_events != reference.global_events().to_vec() {
                    return Err(format!("{n_shards} shards: global-event set diverged"));
                }
                if delivered_events != reference.global_events().to_vec() {
                    return Err(format!(
                        "{n_shards} shards: delivered {} events, reference flagged {}",
                        delivered_events.len(),
                        reference.global_events().len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn burst_workload_actually_triggers_global_events() {
    // Guard against the property above passing vacuously: the workload
    // shape must flag at least one global event.
    let mut rng = Rng::new(42);
    let ranks = 4;
    let workload = gen_workload(&mut rng, ranks, 10, 8);
    let (reference, _) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "burst step must flag a global event"
    );

    // And the sharded constellation delivers it to syncing ranks.
    let (client, handle) = ps::spawn(4, None, usize::MAX >> 1, ranks);
    let mut delivered = 0usize;
    for ops in &workload {
        for (report, delta) in &ops.per_rank {
            client.report(report.clone());
            let (_, events) = client.sync(report.app, report.rank, delta);
            delivered += events.len();
        }
    }
    client.shutdown();
    let fin = handle.join();
    assert_eq!(fin.global_events.len(), reference.global_events().len());
    assert_eq!(delivered, reference.global_events().len());
}
