//! Sharded-vs-single parameter-server equivalence: for random delta/report
//! streams, a [`ShardedPs`](chimbuko::ps::shard) constellation with
//! N ∈ {1, 2, 4, 7} shards must produce bit-identical global `RunStats`,
//! anomaly totals, and global-event sets as the single-threaded
//! [`ParameterServer`] reference — Pébay merges are commutative, so the
//! hash routing must be invisible in the results.
//!
//! The same property extends across the deployment axis: shards served
//! from separate TCP endpoints (`tcp_endpoint_equivalence_matches_reference`)
//! and from separate OS *processes* (`multi_process_ps_smoke`, which
//! launches real `chimbuko ps-shard-server` / `ps-server` children) must
//! be bit-identical too, with the same exactly-once, next-sync
//! global-event delivery order. A killed-and-restarted shard endpoint
//! must heal through the client's reconnect/backoff path
//! (`killed_shard_endpoint_reconnects`).
//!
//! And across the *placement* axis: a rebalance fired mid-run — slot
//! migration, epoch bump, `Rerouted` healing — must be invisible in the
//! results, in-process (`mid_run_rebalance_equivalence`), across TCP
//! endpoints (`tcp_mid_run_rebalance_equivalence`), and across OS
//! processes (the smoke runs with a live skew-driven rebalancer and
//! asserts at least one epoch bump happened mid-run).

use chimbuko::ps::net::PsTcpServer;
use chimbuko::ps::{self, ParameterServer, PsClient, PsRequest, StepStat};
use chimbuko::stats::StatsTable;
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;
use std::sync::mpsc::channel;

/// One step of the generated workload: every rank reports, then syncs.
struct StepOps {
    step: u64,
    /// Per-rank (report, delta) pairs, rank-ordered.
    per_rank: Vec<(StepStat, StatsTable)>,
}

/// Deterministic workload: `quiet` steps of mostly-zero anomaly counts
/// followed by one bursty step (so global-event detection has history to
/// trigger against), with random per-rank stat deltas that cover both the
/// dense (fid < 256) and spill (fid ≥ 256) paths of the stats table.
fn gen_workload(rng: &mut Rng, ranks: usize, quiet_steps: usize, delta_len: usize) -> Vec<StepOps> {
    let mut steps = Vec::new();
    for step in 0..=(quiet_steps as u64) {
        let burst = step == quiet_steps as u64;
        let mut per_rank = Vec::new();
        for rank in 0..ranks as u32 {
            let anoms = if burst {
                4 + rng.usize(4) as u64
            } else {
                u64::from(rank == 0 && step % 3 == 0)
            };
            let report = StepStat {
                app: 0,
                rank,
                step,
                n_executions: 50 + rng.usize(50) as u64,
                n_anomalies: anoms,
                ts_range: (step * 1000, step * 1000 + 999),
            };
            let mut delta = StatsTable::new();
            for _ in 0..delta_len.max(1) {
                let fid = if rng.chance(0.1) {
                    300 + rng.usize(8) as u32 // spill path
                } else {
                    rng.usize(24) as u32 // dense path
                };
                delta.push(fid, rng.lognormal(5.0, 1.0));
            }
            per_rank.push((report, delta));
        }
        steps.push(StepOps { step, per_rank });
    }
    steps
}

/// Drive the single-threaded reference; returns (server, per-sync replies).
fn drive_reference(
    workload: &[StepOps],
    ranks: usize,
) -> (ParameterServer, Vec<Vec<(u32, chimbuko::stats::RunStats)>>) {
    let mut ps = ParameterServer::new(None, usize::MAX >> 1, ranks);
    let mut replies = Vec::new();
    for ops in workload {
        for (report, delta) in &ops.per_rank {
            ps.handle(PsRequest::Report(report.clone()));
            let (rtx, rrx) = channel();
            ps.handle(PsRequest::Sync {
                app: report.app,
                rank: report.rank,
                delta: delta.iter().map(|(f, s)| (f, *s)).collect(),
                reply: rtx,
            });
            replies.push(rrx.recv().unwrap().global);
        }
    }
    (ps, replies)
}

#[test]
fn sharded_equivalence_property() {
    check(
        "sharded-vs-single-ps",
        PropConfig { cases: 12, seed: 0x5AAD, max_size: 24 },
        |rng, size| {
            let ranks = 2 + rng.usize(4);
            let workload = gen_workload(rng, ranks, 8 + rng.usize(4), size);
            let (reference, ref_replies) = drive_reference(&workload, ranks);

            for n_shards in [1usize, 2, 4, 7] {
                let (client, handle) = ps::spawn(n_shards, None, usize::MAX >> 1, ranks);
                let mut reply_idx = 0usize;
                let mut delivered_events = Vec::new();
                for ops in &workload {
                    for (report, delta) in &ops.per_rank {
                        client.report(report.clone());
                        let (global, events) = client.sync(report.app, report.rank, delta);
                        delivered_events.extend(events);
                        // Per-sync reply must match the reference
                        // bit-for-bit (same merge sequence per function).
                        let want = &ref_replies[reply_idx];
                        reply_idx += 1;
                        if global.len() != want.len() {
                            return Err(format!(
                                "{n_shards} shards: reply size {} vs {} at sync {}",
                                global.len(),
                                want.len(),
                                reply_idx
                            ));
                        }
                        for (fid, st) in want {
                            if global.get(*fid) != Some(st) {
                                return Err(format!(
                                    "{n_shards} shards: fid {fid} reply diverged at sync {reply_idx} (step {})",
                                    ops.step
                                ));
                            }
                        }
                    }
                }
                client.shutdown();
                let fin = handle.join();
                // Global stats: bit-identical, every key present.
                if fin.global_len() != reference.global_len() {
                    return Err(format!(
                        "{n_shards} shards: {} global functions vs {}",
                        fin.global_len(),
                        reference.global_len()
                    ));
                }
                for (key, st) in reference.global_iter() {
                    if fin.global.get(&key) != Some(st) {
                        return Err(format!("{n_shards} shards: global stats diverged for {key:?}"));
                    }
                }
                // Anomaly totals and timeline.
                let want_snap = reference.snapshot();
                if fin.snapshot.total_anomalies != want_snap.total_anomalies
                    || fin.snapshot.total_executions != want_snap.total_executions
                {
                    return Err(format!("{n_shards} shards: totals diverged"));
                }
                if fin.snapshot.ranks.len() != want_snap.ranks.len() {
                    return Err(format!("{n_shards} shards: rank summaries diverged"));
                }
                if fin.snapshot.functions_tracked != want_snap.functions_tracked {
                    return Err(format!("{n_shards} shards: functions_tracked diverged"));
                }
                // Global-event sets: same events flagged, all delivered.
                if fin.global_events != reference.global_events().to_vec() {
                    return Err(format!("{n_shards} shards: global-event set diverged"));
                }
                if delivered_events != reference.global_events().to_vec() {
                    return Err(format!(
                        "{n_shards} shards: delivered {} events, reference flagged {}",
                        delivered_events.len(),
                        reference.global_events().len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn burst_workload_actually_triggers_global_events() {
    // Guard against the property above passing vacuously: the workload
    // shape must flag at least one global event.
    let mut rng = Rng::new(42);
    let ranks = 4;
    let workload = gen_workload(&mut rng, ranks, 10, 8);
    let (reference, _) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "burst step must flag a global event"
    );

    // And the sharded constellation delivers it to syncing ranks.
    let (client, handle) = ps::spawn(4, None, usize::MAX >> 1, ranks);
    let mut delivered = 0usize;
    for ops in &workload {
        for (report, delta) in &ops.per_rank {
            client.report(report.clone());
            let (_, events) = client.sync(report.app, report.rank, delta);
            delivered += events.len();
        }
    }
    client.shutdown();
    let fin = handle.join();
    assert_eq!(fin.global_events.len(), reference.global_events().len());
    assert_eq!(delivered, reference.global_events().len());
}

/// Append one hot function to every delta: a single-hot-fid workload is
/// what skews one shard and exercises the rebalancer. The reference
/// sees the same mutated deltas, so equivalence still holds bit-for-bit.
fn add_hot_fid(workload: &mut [StepOps], fid: u32) {
    for ops in workload.iter_mut() {
        for (_, delta) in ops.per_rank.iter_mut() {
            delta.push(fid, 250.0);
        }
    }
}

/// Drive one workload through a routed client and compare every sync
/// reply, the delivered event sequence, the wire stats, and the final
/// joined state against the single-threaded reference — bit for bit.
/// `mid_hook` (when given) fires once at the halfway sync — the
/// mid-run-rebalance tests migrate slots there.
fn assert_client_matches_reference(
    client: &PsClient,
    workload: &[StepOps],
    reference: &ParameterServer,
    ref_replies: &[Vec<(u32, chimbuko::stats::RunStats)>],
    label: &str,
    mid_hook: Option<&dyn Fn()>,
) {
    let total_syncs: usize = workload.iter().map(|o| o.per_rank.len()).sum();
    let mut hook = mid_hook;
    let mut reply_idx = 0usize;
    let mut delivered = Vec::new();
    for ops in workload {
        for (report, delta) in &ops.per_rank {
            if reply_idx >= total_syncs / 2 {
                if let Some(h) = hook.take() {
                    h();
                }
            }
            client.report(report.clone());
            let (global, events) = client.sync(report.app, report.rank, delta);
            delivered.extend(events);
            let want = &ref_replies[reply_idx];
            reply_idx += 1;
            assert_eq!(
                global.len(),
                want.len(),
                "{label}: reply size diverged at sync {reply_idx} (step {})",
                ops.step
            );
            for (fid, st) in want {
                assert_eq!(
                    global.get(*fid),
                    Some(st),
                    "{label}: fid {fid} reply diverged at sync {reply_idx}"
                );
            }
        }
    }
    assert_eq!(
        delivered,
        reference.global_events().to_vec(),
        "{label}: delivered event sequence diverged"
    );
    // Totals and event sets through the front-end's wire stats.
    let stats = client.stats().unwrap_or_else(|| panic!("{label}: wire stats unavailable"));
    let want_snap = reference.snapshot();
    assert_eq!(stats.total_anomalies, want_snap.total_anomalies, "{label}: anomaly totals");
    assert_eq!(stats.total_executions, want_snap.total_executions, "{label}: execution totals");
    assert_eq!(stats.ranks as usize, want_snap.ranks.len(), "{label}: rank count");
    assert_eq!(
        stats.global_events,
        reference.global_events().to_vec(),
        "{label}: global-event set"
    );
    // Every sync followed this rank's report, so the gate forced exactly
    // one aggregator fetch per sync — the order next-sync delivery needs.
    assert_eq!(client.agg_fetch_count(), reply_idx as u64, "{label}: fetch per dirty sync");
}

#[test]
fn tcp_endpoint_equivalence_matches_reference() {
    // "N shards across N endpoints ≡ single-threaded reference": the
    // same workload, but every stat shard behind its own TCP endpoint
    // and the client routed through the front-end's hello topology.
    let mut rng = Rng::new(0xE2E);
    let ranks = 3;
    let workload = gen_workload(&mut rng, ranks, 10, 8);
    let (reference, ref_replies) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "workload must flag a global event or the delivery check is vacuous"
    );

    for n_shards in [2usize, 4] {
        let (local_client, handle) = ps::spawn(n_shards, None, usize::MAX >> 1, ranks);
        let shard_srvs = handle.serve_shard_endpoints().unwrap();
        let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
        let front =
            PsTcpServer::start_with_topology("127.0.0.1:0", local_client.clone(), addrs).unwrap();
        let client = PsClient::connect(&front.addr().to_string()).unwrap();
        assert_eq!(client.shard_count(), n_shards);
        let label = format!("{n_shards} endpoints");
        assert_client_matches_reference(&client, &workload, &reference, &ref_replies, &label, None);
        drop(front);
        drop(shard_srvs);
        local_client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), reference.global_len(), "{label}: global size");
        for (key, st) in reference.global_iter() {
            assert_eq!(fin.global.get(&key), Some(st), "{label}: stats diverged for {key:?}");
        }
        assert_eq!(fin.global_events, reference.global_events().to_vec(), "{label}: events");
        let want_snap = reference.snapshot();
        assert_eq!(fin.snapshot.total_anomalies, want_snap.total_anomalies, "{label}");
        assert_eq!(fin.snapshot.total_executions, want_snap.total_executions, "{label}");
        assert_eq!(fin.snapshot.functions_tracked, want_snap.functions_tracked, "{label}");
    }
}

#[test]
fn killed_shard_endpoint_reconnects() {
    let (local_client, handle) = ps::spawn(2, None, usize::MAX >> 1, 1);
    let mut shard_srvs = handle.serve_shard_endpoints().unwrap();
    let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
    let front =
        PsTcpServer::start_with_topology("127.0.0.1:0", local_client.clone(), addrs).unwrap();
    let client = PsClient::connect(&front.addr().to_string()).unwrap();

    let fid0 = (0..256u32).find(|&f| ps::shard_of(0, f, 2) == 0).unwrap();
    let fid1 = (0..256u32).find(|&f| ps::shard_of(0, f, 2) == 1).unwrap();
    let mut delta = StatsTable::new();
    delta.push(fid0, 1.0);
    delta.push(fid1, 1.0);

    let (g1, _) = client.sync(0, 0, &delta);
    assert_eq!(g1.get(fid0).unwrap().count(), 1);
    assert_eq!(g1.get(fid1).unwrap().count(), 1);

    // Kill shard endpoint 0: listener closed AND live connections
    // severed — exactly what a crashed ps-shard-server looks like. The
    // shard *state* survives in its thread (it outlives its transport).
    let addr0 = shard_srvs[0].addr().to_string();
    shard_srvs[0].stop();
    let (g2, _) = client.sync(0, 0, &delta);
    assert!(g2.get(fid0).is_none(), "killed shard's slice must degrade, not hang");
    assert_eq!(g2.get(fid1).unwrap().count(), 2, "healthy shard unaffected");

    // Restart the endpoint on the same port, same shard state; the
    // client's reconnector redials after its backoff and the view heals.
    let revived = handle.serve_shard_endpoint_at(0, &addr0).unwrap();
    std::thread::sleep(std::time::Duration::from_millis(400));
    let (g3, _) = client.sync(0, 0, &delta);
    assert_eq!(
        g3.get(fid0).map(|s| s.count()),
        Some(2),
        "reconnected: the sync during the outage was lost (at-most-once), later ones land"
    );
    assert_eq!(g3.get(fid1).unwrap().count(), 3);

    drop(revived);
    drop(front);
    drop(shard_srvs);
    local_client.shutdown();
    let fin = handle.join();
    assert_eq!(fin.global_stats(0, fid0).unwrap().count(), 2);
    assert_eq!(fin.global_stats(0, fid1).unwrap().count(), 3);
}

#[test]
fn multi_process_ps_smoke() {
    // The real thing: two `chimbuko ps-shard-server` OS processes, one
    // `chimbuko ps-server` front-end process wired to them, and a routed
    // client in this process — bit-identical to the reference.
    use std::io::{BufRead, BufReader};
    use std::process::{Child, Command, Stdio};

    struct ChildGuard(Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    fn spawn_server(args: &[&str], marker: &str) -> (ChildGuard, String) {
        let mut child = Command::new(env!("CARGO_BIN_EXE_chimbuko"))
            .args(args)
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning chimbuko server process");
        let stdout = child.stdout.take().expect("child stdout");
        let mut line = String::new();
        BufReader::new(stdout)
            .read_line(&mut line)
            .expect("reading server banner");
        let addr = line
            .rsplit(marker)
            .next()
            .and_then(|s| s.split_whitespace().next())
            .unwrap_or_default()
            .to_string();
        assert!(addr.contains(':'), "could not parse address from banner: {line:?}");
        (ChildGuard(child), addr)
    }

    let (_s0, a0) = spawn_server(
        &["ps-shard-server", "--addr", "127.0.0.1:0", "--shard-id", "0", "--shards", "2"],
        "listening on ",
    );
    let (_s1, a1) = spawn_server(
        &["ps-shard-server", "--addr", "127.0.0.1:0", "--shard-id", "1", "--shards", "2"],
        "listening on ",
    );
    let ranks = 3usize;
    let endpoints = format!("{a0},{a1}");
    // A live skew-driven rebalancer in the front-end process: low
    // trigger ratio + tiny window floor so the hot-fid workload below
    // fires at least one rebalance mid-run.
    let (_fe, fa) = spawn_server(
        &[
            "ps-server",
            "--addr",
            "127.0.0.1:0",
            "--endpoints",
            &endpoints,
            "--ranks",
            &ranks.to_string(),
            "--publish-every",
            "1000000",
            "--rebalance-interval-ms",
            "100",
            "--rebalance-max-ratio",
            "1.05",
            "--rebalance-min-merges",
            "1",
        ],
        "server on ",
    );

    let client = PsClient::connect(&fa).expect("connecting to front-end process");
    assert_eq!(client.shard_count(), 2);

    let mut rng = Rng::new(0xBEEF);
    let mut workload = gen_workload(&mut rng, ranks, 8, 6);
    // Eight hot functions, all on shard 0 at epoch 0: every delta then
    // lands ≥ 8 merges on shard 0 while the random tail adds ≤ 6, so the
    // windowed max/mean is ≥ 8/7 no matter how the tail splits — the
    // skew-driven trigger (1.05) fires deterministically.
    let hot: Vec<u32> = (0..64u32).filter(|&f| ps::shard_of(0, f, 2) == 0).take(8).collect();
    assert_eq!(hot.len(), 8);
    for &f in &hot {
        add_hot_fid(&mut workload, f);
    }
    let (reference, ref_replies) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "workload must flag a global event or the delivery check is vacuous"
    );
    // Halfway through, park long enough for the front-end's rebalance
    // cadence to judge the skewed first half and migrate (wire
    // migrate/install between the two shard-server processes).
    let park = || std::thread::sleep(std::time::Duration::from_millis(500));
    assert_client_matches_reference(
        &client,
        &workload,
        &reference,
        &ref_replies,
        "multi-process",
        Some(&park),
    );
    assert!(
        client.placement_epoch() > 0,
        "the skewed first half must have triggered a mid-run rebalance"
    );
    assert!(
        client.reroute_count() > 0,
        "the routed client must have healed through Rerouted after the epoch bump"
    );
}

#[test]
fn flooded_shard_endpoint_sheds_while_behaved_replies_stay_bit_identical() {
    // End-to-end backpressure through the public surface: a client that
    // floods sync frames and never drains replies must be shed with
    // `Busy` (visible in the endpoint's transport counters and shard
    // snapshot), while a well-behaved client on the same endpoint gets
    // replies bit-identical to an uncontended endpoint's.
    use chimbuko::ps::net::PsShardTcpServer;
    use chimbuko::util::net::ReactorOpts;
    use chimbuko::util::wire::{read_msg, write_msg, Cursor};
    use std::net::TcpStream;

    // Shard-endpoint kind bytes, from the protocol doc in `ps::net`.
    const KIND_HELLO: u8 = 3;
    const KIND_SHARD_SYNC: u8 = 6;
    const KIND_SHARD_SNAPSHOT: u8 = 8;

    // Hand-rolled sync frame: kind, app, epoch, entry count, then
    // (fid u32, count u64, mean/m2/min/max f64) per entry.
    fn sync_msg(first_fid: u32, n: u32, v: f64) -> Vec<u8> {
        let mut msg = vec![KIND_SHARD_SYNC];
        msg.extend_from_slice(&0u32.to_le_bytes());
        msg.extend_from_slice(&0u64.to_le_bytes());
        msg.extend_from_slice(&n.to_le_bytes());
        for fid in first_fid..first_fid + n {
            msg.extend_from_slice(&fid.to_le_bytes());
            msg.extend_from_slice(&1u64.to_le_bytes());
            msg.extend_from_slice(&v.to_le_bytes());
            msg.extend_from_slice(&0f64.to_le_bytes());
            msg.extend_from_slice(&v.to_le_bytes());
            msg.extend_from_slice(&v.to_le_bytes());
        }
        msg
    }

    // Hello + ten sync rounds over fids 0..64, raw reply bytes returned
    // so the flooded/quiet comparison is bit-for-bit.
    fn behaved_replies(addr: &str) -> (TcpStream, Vec<Vec<u8>>) {
        let mut s = TcpStream::connect(addr).unwrap();
        write_msg(&mut s, &[KIND_HELLO]).unwrap();
        let hello = read_msg(&mut s).unwrap().expect("hello reply");
        let mut c = Cursor::new(&hello);
        assert_eq!(c.u32().unwrap(), 0, "shard id");
        assert_eq!(c.u32().unwrap(), 1, "shard count");
        let mut replies = Vec::new();
        for round in 0..10u32 {
            write_msg(&mut s, &sync_msg(0, 64, 1.0 + f64::from(round))).unwrap();
            replies.push(read_msg(&mut s).unwrap().expect("sync reply"));
        }
        (s, replies)
    }

    // Tiny per-connection reply budget so the flood trips admission
    // control without tens of MB; huge server-wide bound keeps the
    // flooded connection alive (shed, not severed).
    let quiet = PsShardTcpServer::spawn_standalone_with_opts(
        "127.0.0.1:0",
        0,
        1,
        ReactorOpts::new(1, 32 * 1024, 1 << 30),
    )
    .unwrap();
    let flooded = PsShardTcpServer::spawn_standalone_with_opts(
        "127.0.0.1:0",
        0,
        1,
        ReactorOpts::new(1, 32 * 1024, 1 << 30),
    )
    .unwrap();

    // Flood: 256 frames whose replies echo 2048 entries (~90 KiB) each,
    // on fids disjoint from the behaved client's, replies never read.
    let mut flood = TcpStream::connect(&flooded.addr().to_string()).unwrap();
    let big = sync_msg(1_000_000, 2048, 1.0);
    for _ in 0..256 {
        if write_msg(&mut flood, &big).is_err() {
            break; // severed under the hard bound — acceptable
        }
    }
    let stats = flooded.net_stats();
    let t0 = std::time::Instant::now();
    while stats.shed_count() == 0 && t0.elapsed() < std::time::Duration::from_secs(10) {
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(stats.shed_count() > 0, "non-draining flood must be shed");

    let (_q, want) = behaved_replies(&quiet.addr().to_string());
    let (mut f, got) = behaved_replies(&flooded.addr().to_string());
    assert_eq!(want, got, "behaved replies must be bit-identical under flood");

    // The shard snapshot carries the shed counter to operators.
    write_msg(&mut f, &[KIND_SHARD_SNAPSHOT]).unwrap();
    let snap = read_msg(&mut f).unwrap().expect("snapshot reply");
    let mut c = Cursor::new(&snap);
    for _ in 0..3 {
        c.u64().unwrap(); // functions, syncs, merges
    }
    c.u32().unwrap(); // shard id
    c.u64().unwrap(); // placement epoch
    c.u32().unwrap(); // slots
    assert!(c.u64().unwrap() > 0, "snapshot must carry the shed counter");
    drop(flood);
}

#[test]
fn mid_run_rebalance_equivalence() {
    // Rebalance fired mid-run, in-process: migrate a handful of slots
    // (including the hot function's) halfway through the workload; every
    // reply, the delivered event order, and the final joined state must
    // stay bit-identical to the static-placement reference.
    let mut rng = Rng::new(0x4EBA);
    let ranks = 3;
    let mut workload = gen_workload(&mut rng, ranks, 10, 8);
    add_hot_fid(&mut workload, 7);
    let (reference, ref_replies) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "workload must flag a global event or the delivery check is vacuous"
    );

    for n_shards in [2usize, 4] {
        let (client, handle) = ps::spawn(n_shards, None, usize::MAX >> 1, ranks);
        let migrate = || {
            let p = handle.placement();
            let mut moves: Vec<(usize, u32)> = Vec::new();
            for fid in [7u32, 0, 3] {
                let slot = chimbuko::placement::Placement::slot_of(0, fid);
                if moves.iter().any(|&(s, _)| s == slot) {
                    continue;
                }
                let cur = p.shard_of_slot(slot) as u32;
                moves.push((slot, (cur + 1) % n_shards as u32));
            }
            let epoch = handle.migrate_slots(&moves).expect("mid-run migration");
            assert_eq!(epoch, 1);
        };
        let label = format!("{n_shards} shards mid-rebalance");
        assert_client_matches_reference(
            &client,
            &workload,
            &reference,
            &ref_replies,
            &label,
            Some(&migrate),
        );
        assert_eq!(client.placement_epoch(), 1, "{label}: epoch must have bumped");
        client.shutdown();
        let fin = handle.join();
        assert_eq!(fin.global_len(), reference.global_len(), "{label}: global size");
        for (key, st) in reference.global_iter() {
            assert_eq!(fin.global.get(&key), Some(st), "{label}: stats diverged for {key:?}");
        }
        assert_eq!(fin.global_events, reference.global_events().to_vec(), "{label}: events");
        assert_eq!(fin.snapshot.placement_epoch, 1, "{label}: snapshot epoch");
        let want_snap = reference.snapshot();
        assert_eq!(fin.snapshot.total_anomalies, want_snap.total_anomalies, "{label}");
        assert_eq!(fin.snapshot.total_executions, want_snap.total_executions, "{label}");
        assert_eq!(fin.snapshot.functions_tracked, want_snap.functions_tracked, "{label}");
    }
}

#[test]
fn tcp_mid_run_rebalance_equivalence() {
    // The acceptance shape: a rebalance fired mid-run across TCP
    // endpoints. The routed client learns about the epoch bump only
    // through a Rerouted bounce, refreshes its table from the front-end,
    // resends the bounced sub-frames — and stays bit-identical.
    let mut rng = Rng::new(0x7EBA);
    let ranks = 3;
    let mut workload = gen_workload(&mut rng, ranks, 10, 8);
    add_hot_fid(&mut workload, 7);
    let (reference, ref_replies) = drive_reference(&workload, ranks);
    assert!(
        !reference.global_events().is_empty(),
        "workload must flag a global event or the delivery check is vacuous"
    );

    let n_shards = 4usize;
    let (local_client, handle) = ps::spawn(n_shards, None, usize::MAX >> 1, ranks);
    let shard_srvs = handle.serve_shard_endpoints().unwrap();
    let addrs: Vec<String> = shard_srvs.iter().map(|s| s.addr().to_string()).collect();
    let front =
        PsTcpServer::start_with_topology("127.0.0.1:0", local_client.clone(), addrs).unwrap();
    let client = PsClient::connect(&front.addr().to_string()).unwrap();
    assert_eq!(client.placement_epoch(), 0);

    let migrate = || {
        let p = handle.placement();
        let slot = chimbuko::placement::Placement::slot_of(0, 7);
        let cur = p.shard_of_slot(slot) as u32;
        let epoch = handle.migrate_slots(&[(slot, (cur + 1) % n_shards as u32)]).unwrap();
        assert_eq!(epoch, 1);
    };
    assert_client_matches_reference(
        &client,
        &workload,
        &reference,
        &ref_replies,
        "tcp mid-rebalance",
        Some(&migrate),
    );
    assert!(
        client.reroute_count() > 0,
        "stale-epoch frames must have bounced and healed"
    );
    assert_eq!(client.placement_epoch(), 1, "client must have refreshed to epoch 1");

    drop(front);
    drop(shard_srvs);
    local_client.shutdown();
    let fin = handle.join();
    assert_eq!(fin.global_len(), reference.global_len());
    for (key, st) in reference.global_iter() {
        assert_eq!(fin.global.get(&key), Some(st), "stats diverged for {key:?}");
    }
    assert_eq!(fin.global_events, reference.global_events().to_vec());
    assert_eq!(fin.snapshot.placement_epoch, 1);
}
