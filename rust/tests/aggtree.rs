//! Tree-vs-flat aggregator bit-equivalence: a constellation with
//! `PsOpts::agg_fanout ≥ 2` spreads the aggregator into the hierarchical
//! fold tree (`chimbuko::aggtree`), and the tree must be *invisible* in
//! results — per-sync replies, delivered global events and their order,
//! query snapshots, every published viz delta, and the final joined
//! state must be bit-identical to the flat single-thread aggregator,
//! across fanouts {2, 4} and depths {2, 3}
//! (`tree_is_bit_equivalent_to_flat_in_process`) and with a leaf hosted
//! by a real `chimbuko agg-node` OS process
//! (`tree_with_remote_agg_node_process_stays_bit_equivalent`).
//!
//! Two planes are excluded from the snapshot fingerprints, by design:
//! `agg_nodes` (tree-only fold counters — the flat aggregator publishes
//! none) and the shard plane (`shard_loads`, per-publish
//! `functions_tracked`), whose counters are gathered by the merge stage
//! concurrently with in-flight syncs in *both* shapes. The shard plane
//! is still pinned at join time, where it is race-free.
//!
//! The driver quiesces with a `Query` barrier between each round's
//! reports and its syncs: the flat aggregator's single channel orders
//! one rank's fetch behind *every* rank's reports for free, while the
//! tree only orders it behind the reports of the leaves it traverses —
//! the barrier removes that (benign) timing freedom so delivery can be
//! compared sync-by-sync instead of merely end-to-end.

use chimbuko::ps::{self, GlobalEvent, PsOpts, StepStat, VizSnapshot};
use chimbuko::stats::{RunStats, StatsTable};
use chimbuko::util::rng::Rng;
use std::collections::HashMap;
use std::sync::mpsc::channel;

/// One step round of the generated workload: every rank reports, then
/// (after the barrier) syncs.
struct StepOps {
    /// Per-rank (report, delta) pairs, rank-ordered.
    per_rank: Vec<(StepStat, StatsTable)>,
}

/// Deterministic workload, same shape as the sharded-equivalence suite:
/// `quiet` steps of mostly-zero anomaly counts followed by one bursty
/// step (global-event detection needs history to trigger against), with
/// random per-rank deltas covering the dense and spill stat-table paths.
fn gen_workload(rng: &mut Rng, ranks: usize, quiet_steps: usize, delta_len: usize) -> Vec<StepOps> {
    let mut steps = Vec::new();
    for step in 0..=(quiet_steps as u64) {
        let burst = step == quiet_steps as u64;
        let mut per_rank = Vec::new();
        for rank in 0..ranks as u32 {
            let anoms = if burst {
                4 + rng.usize(4) as u64
            } else {
                u64::from(rank == 0 && step % 3 == 0)
            };
            let report = StepStat {
                app: 0,
                rank,
                step,
                n_executions: 50 + rng.usize(50) as u64,
                n_anomalies: anoms,
                ts_range: (step * 1000, step * 1000 + 999),
            };
            let mut delta = StatsTable::new();
            for _ in 0..delta_len.max(1) {
                let fid = if rng.chance(0.1) {
                    300 + rng.usize(8) as u32 // spill path
                } else {
                    rng.usize(24) as u32 // dense path
                };
                delta.push(fid, rng.lognormal(5.0, 1.0));
            }
            per_rank.push((report, delta));
        }
        steps.push(StepOps { per_rank });
    }
    steps
}

fn stats_fp(s: &RunStats) -> String {
    format!(
        "{}:{:x}:{:x}:{:x}:{:x}",
        s.count(),
        s.mean().to_bits(),
        s.m2().to_bits(),
        s.min().to_bits(),
        s.max().to_bits()
    )
}

fn event_fp(e: &GlobalEvent) -> String {
    format!("{}:{}:{:x}", e.step, e.total_anomalies, e.score.to_bits())
}

fn step_fp(s: &StepStat) -> String {
    format!(
        "{}/{}/{}/{}/{}/{}..{}",
        s.app, s.rank, s.step, s.n_executions, s.n_anomalies, s.ts_range.0, s.ts_range.1
    )
}

/// Canonical aggregator-plane fingerprint of a snapshot (see the module
/// doc for what is excluded and why).
fn snap_fp(s: &VizSnapshot) -> String {
    let ranks: Vec<String> = s
        .ranks
        .iter()
        .map(|r| format!("{}:{}:{}:{}", r.app, r.rank, stats_fp(&r.step_counts), r.total_anomalies))
        .collect();
    let fresh: Vec<String> = s.fresh_steps.iter().map(step_fp).collect();
    let events: Vec<String> = s.global_events.iter().map(event_fp).collect();
    format!(
        "delta={} ranks=[{}] fresh=[{}] anoms={} execs={} events=[{}] epoch={}",
        s.delta,
        ranks.join(","),
        fresh.join(","),
        s.total_anomalies,
        s.total_executions,
        events.join(","),
        s.placement_epoch
    )
}

/// Everything one run produces that the equivalence contract covers.
struct RunOut {
    /// Per-sync stat replies, in issue order.
    sync_replies: Vec<Vec<(u32, RunStats)>>,
    /// Per-sync delivered events, in issue order (exactly-once delivery
    /// means most entries are empty; position matters).
    sync_events: Vec<Vec<GlobalEvent>>,
    /// Query-barrier observations, one per step round.
    barriers: Vec<String>,
    /// Published viz deltas (canonicalized), in publish order.
    published: Vec<String>,
    final_fp: String,
    final_global: HashMap<(u32, u32), RunStats>,
    final_events: Vec<GlobalEvent>,
    final_functions: u64,
    final_sync_count: u64,
    /// Largest `agg_nodes` count seen in a published snapshot (0 under
    /// the flat aggregator) and the deepest node depth reported.
    agg_nodes_seen: usize,
    agg_depth_seen: u32,
}

fn drive(
    workload: &[StepOps],
    ranks: usize,
    publish_every: usize,
    agg_fanout: usize,
    agg_endpoints: Vec<String>,
) -> RunOut {
    let (viz_tx, viz_rx) = channel();
    let (client, handle) = ps::spawn_with(PsOpts {
        shards: 2,
        viz_tx: Some(viz_tx),
        publish_every,
        reports_per_step: ranks,
        agg_fanout,
        agg_endpoints,
        ..PsOpts::default()
    })
    .expect("spawning ps constellation");

    let mut sync_replies = Vec::new();
    let mut sync_events = Vec::new();
    let mut barriers = Vec::new();
    for ops in workload {
        for (report, _) in &ops.per_rank {
            client.report(report.clone());
        }
        let st = client.stats().expect("query barrier");
        barriers.push(format!(
            "anoms={} execs={} ranks={} ver={} events=[{}]",
            st.total_anomalies,
            st.total_executions,
            st.ranks,
            st.event_version,
            st.global_events.iter().map(event_fp).collect::<Vec<_>>().join(",")
        ));
        for (report, delta) in &ops.per_rank {
            let (global, events) = client.sync(report.app, report.rank, delta);
            sync_replies.push(global.iter().map(|(f, s)| (f, *s)).collect());
            sync_events.push(events);
        }
    }
    client.shutdown();
    let fin = handle.join();
    let mut published = Vec::new();
    let mut agg_nodes_seen = 0usize;
    let mut agg_depth_seen = 0u32;
    for snap in viz_rx.iter() {
        agg_nodes_seen = agg_nodes_seen.max(snap.agg_nodes.len());
        agg_depth_seen =
            agg_depth_seen.max(snap.agg_nodes.iter().map(|n| n.depth).max().unwrap_or(0));
        published.push(snap_fp(&snap));
    }
    RunOut {
        sync_replies,
        sync_events,
        barriers,
        published,
        final_fp: snap_fp(&fin.snapshot),
        final_functions: fin.snapshot.functions_tracked,
        final_global: fin.global,
        final_events: fin.global_events,
        final_sync_count: fin.sync_count,
        agg_nodes_seen,
        agg_depth_seen,
    }
}

fn assert_equivalent(flat: &RunOut, tree: &RunOut, label: &str) {
    assert_eq!(flat.sync_replies, tree.sync_replies, "{label}: per-sync stat replies diverged");
    assert_eq!(
        flat.sync_events, tree.sync_events,
        "{label}: per-sync event delivery (set or order) diverged"
    );
    assert_eq!(flat.barriers, tree.barriers, "{label}: query snapshots diverged");
    assert_eq!(flat.published, tree.published, "{label}: published viz deltas diverged");
    assert_eq!(flat.final_fp, tree.final_fp, "{label}: final snapshot diverged");
    assert_eq!(flat.final_global, tree.final_global, "{label}: final global stats diverged");
    assert_eq!(flat.final_events, tree.final_events, "{label}: final event set diverged");
    assert_eq!(flat.final_functions, tree.final_functions, "{label}: functions_tracked diverged");
    assert_eq!(flat.final_sync_count, tree.final_sync_count, "{label}: sync counts diverged");
}

#[test]
fn tree_is_bit_equivalent_to_flat_in_process() {
    let mut rng = Rng::new(0xA66);
    // Fanout × rank-count pairs covering depths 2 and 3 at both fanouts.
    for (fanout, ranks) in [(2usize, 4usize), (2, 8), (4, 8), (4, 32)] {
        let spec = chimbuko::aggtree::TreeSpec::plan(fanout, ranks);
        let workload = gen_workload(&mut rng, ranks, 10, 6);
        let label = format!("fanout {fanout} x {ranks} ranks (depth {})", spec.depth());

        let flat = drive(&workload, ranks, ranks, 0, Vec::new());
        assert!(
            !flat.final_events.is_empty(),
            "{label}: workload must flag a global event or the equivalence is vacuous"
        );
        // Every rank syncs after the burst round's barrier, so each
        // flagged event is delivered exactly once *per rank* (per-rank
        // delivery cursors).
        assert_eq!(
            flat.sync_events.iter().flatten().count(),
            flat.final_events.len() * ranks,
            "{label}: every flagged event must reach every rank exactly once"
        );
        assert_eq!(flat.agg_nodes_seen, 0, "{label}: flat publishes no agg-node loads");

        let tree = drive(&workload, ranks, ranks, fanout, Vec::new());
        assert_eq!(
            tree.agg_nodes_seen,
            spec.nodes(),
            "{label}: every tree node must publish its fold counters"
        );
        assert_eq!(
            tree.agg_depth_seen as usize,
            spec.depth() - 1,
            "{label}: the deepest published node must be a leaf"
        );
        assert_equivalent(&flat, &tree, &label);
    }
}

#[test]
fn tree_with_remote_agg_node_process_stays_bit_equivalent() {
    // The real thing: one leaf of a fanout-2, 4-rank tree hosted by a
    // `chimbuko agg-node` OS process (protocol kinds 13–16), the rest of
    // the tree in-process — still bit-identical to flat.
    use std::io::{BufRead, BufReader};
    use std::process::{Child, Command, Stdio};

    struct ChildGuard(Child);
    impl Drop for ChildGuard {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    let (fanout, ranks) = (2usize, 4usize);
    let spec = chimbuko::aggtree::TreeSpec::plan(fanout, ranks);
    assert_eq!(spec.leaves(), 2);
    let leaf = 1usize; // ranks [2, 4) live in the child process
    let (lo, hi) = spec.leaf_range(leaf);
    let node = spec.node_id(0, leaf);

    let mut child = Command::new(env!("CARGO_BIN_EXE_chimbuko"))
        .args([
            "agg-node",
            "--addr",
            "127.0.0.1:0",
            "--node",
            &node.to_string(),
            "--depth",
            &spec.node_depth(0).to_string(),
            "--rank-lo",
            &lo.to_string(),
            "--rank-hi",
            &hi.to_string(),
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning chimbuko agg-node process");
    let stdout = child.stdout.take().expect("child stdout");
    let guard = ChildGuard(child);
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).expect("reading agg-node banner");
    let addr = line
        .rsplit("listening on ")
        .next()
        .and_then(|s| s.split_whitespace().next())
        .unwrap_or_default()
        .to_string();
    assert!(addr.contains(':'), "could not parse address from banner: {line:?}");

    let mut rng = Rng::new(0xA66E);
    let workload = gen_workload(&mut rng, ranks, 10, 6);
    let flat = drive(&workload, ranks, ranks, 0, Vec::new());
    assert!(
        !flat.final_events.is_empty(),
        "workload must flag a global event or the equivalence is vacuous"
    );

    // Leaf 0 stays in-process (empty endpoint slot), leaf 1 is the child.
    let tree = drive(&workload, ranks, ranks, fanout, vec![String::new(), addr]);
    assert_eq!(
        tree.agg_nodes_seen,
        spec.nodes(),
        "remote leaf's fold counters must reach the published snapshots too"
    );
    assert_equivalent(&flat, &tree, "remote agg-node leaf");
    drop(guard);
}

/// Whole-range outage: every rank of one leaf goes silent mid-run, long
/// enough that the stalled step accumulators cross the expiry horizon,
/// then resumes in time for a burst step to flag a global event.
///
/// The flat aggregator advances its horizon on *every* report, so the
/// stalled steps' partial totals fold into the step statistics on a
/// fixed schedule — and the burst event's score is computed over that
/// history. A tree leaf's range fold only advances on its *own* ranks'
/// reports, so without the flush-horizon reconciliation the silent
/// leaf's accumulator freezes: its stranded contribution never reaches
/// the step statistics (and, once the ranks resume, is shed at the root
/// as a straggler), skewing the event score. This pins both shapes to
/// the same expiry schedule, bit for bit.
#[test]
fn whole_range_outage_expires_on_the_flat_schedule() {
    use chimbuko::ps::STEP_ACC_MAX_LAG;
    let ranks = 8usize;
    let fanout = 2usize;
    let spec = chimbuko::aggtree::TreeSpec::plan(fanout, ranks);
    assert_eq!(spec.leaf_range(3), (6, 8), "leaf 3 must own the stalled ranks");

    let cut = 6u64; // rank 7 misses this step entirely; rank 6 half-reports it
    let resume = cut + STEP_ACC_MAX_LAG + 4; // long past the expiry horizon
    let last = resume + 12; // quorum history rebuilt, then the burst
    let mut workload = Vec::new();
    for step in 0..=last {
        let mut per_rank = Vec::new();
        for rank in 0..ranks as u32 {
            let silent = match rank {
                6 => step > cut && step < resume,
                7 => step >= cut && step < resume,
                _ => false,
            };
            if silent {
                continue;
            }
            let anoms = if step == last {
                5 + u64::from(rank % 3) // the burst the §V trigger flags
            } else if rank == 6 && step == cut {
                3 // the contribution stranded in the silent leaf's fold
            } else {
                u64::from(rank == 0 && step % 3 == 0)
            };
            let report = StepStat {
                app: 0,
                rank,
                step,
                n_executions: 40 + rank as u64,
                n_anomalies: anoms,
                ts_range: (step * 1000, step * 1000 + 999),
            };
            // Small exact-arithmetic deltas: the outage plane is the
            // aggregator, not the shards.
            let mut delta = StatsTable::new();
            delta.push(rank % 4, (step % 7 + 1) as f64);
            per_rank.push((report, delta));
        }
        workload.push(StepOps { per_rank });
    }

    // Per-report publishing keeps flat and tree publish windows aligned
    // even though outage rounds carry fewer reports than the cadence.
    let flat = drive(&workload, ranks, 1, 0, Vec::new());
    assert!(
        !flat.final_events.is_empty(),
        "the burst after the outage must flag a global event, or the \
         expiry-schedule comparison is vacuous"
    );
    assert_eq!(
        flat.sync_events.iter().flatten().count(),
        flat.final_events.len() * ranks,
        "resumed ranks must receive the event exactly once too"
    );
    let tree = drive(&workload, ranks, 1, fanout, Vec::new());
    assert_equivalent(&flat, &tree, "whole-range outage");
}
