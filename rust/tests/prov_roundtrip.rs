//! Property: `ProvRecord` JSONL serialization and the `ProvDb::load`
//! index are faithful — write N random records to disk, reload, and the
//! store answers every query and call-stack request identically to the
//! original in-memory index.

use chimbuko::provenance::{ProvDb, ProvQuery, ProvRecord};
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;
use std::path::PathBuf;

/// Random record; `entry_us`/`score` ranges are disjoint per `i` so that
/// global orderings are unambiguous across the per-(app,rank) files
/// `ProvDb::load` reads back in path order (within one file the relative
/// order is preserved; across files only the sort keys order records).
fn record(rng: &mut Rng, i: u64) -> ProvRecord {
    let entry = i * 1_000 + rng.range_u64(0, 999);
    let dur = rng.range_u64(1, 5_000);
    let score = i as f64 * 0.5 + rng.range_f64(0.0, 0.4);
    let label = ["normal", "anomaly_high", "anomaly_low"][rng.usize(3)];
    ProvRecord {
        call_id: i,
        app: rng.usize(2) as u32,
        rank: rng.usize(4) as u32,
        thread: rng.usize(2) as u32,
        fid: rng.usize(7) as u32,
        // Exercise the JSON escaping path too.
        func: format!("FN_{}_\"q\"\n", rng.usize(7)),
        step: rng.usize(5) as u64,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: rng.range_u64(0, dur),
        depth: rng.usize(4) as u32,
        parent: if rng.chance(0.4) { Some(rng.range_u64(0, 1 << 40)) } else { None },
        n_children: rng.usize(5) as u32,
        n_messages: rng.usize(5) as u32,
        msg_bytes: rng.range_u64(0, 1 << 20),
        label: label.to_string(),
        score,
    }
}

fn queries() -> Vec<ProvQuery> {
    let mut qs = vec![
        ProvQuery::default(),
        ProvQuery { anomalies_only: true, ..Default::default() },
        ProvQuery { order_by_score: true, limit: Some(9), ..Default::default() },
        ProvQuery { min_score: Some(3.0), order_by_score: true, ..Default::default() },
        ProvQuery { label: Some("anomaly_low".to_string()), ..Default::default() },
        ProvQuery { step_range: Some((1, 3)), ..Default::default() },
        ProvQuery { ts_range: Some((5_000, 40_000)), ..Default::default() },
    ];
    for app in 0..2u32 {
        for rank in 0..4u32 {
            qs.push(ProvQuery { rank: Some((app, rank)), ..Default::default() });
        }
        for fid in 0..7u32 {
            qs.push(ProvQuery { fid: Some((app, fid)), ..Default::default() });
        }
    }
    qs
}

fn tmpdir(tag: u64) -> PathBuf {
    std::env::temp_dir().join(format!(
        "chimbuko-prov-rt-{}-{tag}",
        std::process::id()
    ))
}

#[test]
fn prop_provdb_reload_answers_queries_identically() {
    check(
        "provdb-reload-equivalence",
        PropConfig { cases: 12, seed: 0x90B0, max_size: 120 },
        |rng, size| {
            let n = (size as u64).max(4);
            let dir = tmpdir(rng.range_u64(0, u64::MAX / 2));
            std::fs::remove_dir_all(&dir).ok();
            let mut db = ProvDb::create(&dir).map_err(|e| e.to_string())?;
            for i in 0..n {
                db.append_record(record(rng, i)).map_err(|e| e.to_string())?;
            }
            db.flush().map_err(|e| e.to_string())?;

            let loaded = ProvDb::load(&dir).map_err(|e| e.to_string())?;
            let result = (|| -> Result<(), String> {
                if loaded.len() != db.len() {
                    return Err(format!("len {} vs {}", loaded.len(), db.len()));
                }
                if loaded.anomaly_count() != db.anomaly_count() {
                    return Err("anomaly count diverged".to_string());
                }
                if loaded.bytes_written() != db.bytes_written() {
                    return Err("byte accounting diverged".to_string());
                }
                for q in queries() {
                    let want = db.query(&q);
                    let got = loaded.query(&q);
                    if want.len() != got.len() {
                        return Err(format!(
                            "query {q:?}: {} vs {} results",
                            got.len(),
                            want.len()
                        ));
                    }
                    for (g, w) in got.iter().zip(want.iter()) {
                        if g != w {
                            return Err(format!("query {q:?} diverged at call {}", w.call_id));
                        }
                    }
                }
                for app in 0..2u32 {
                    for rank in 0..4u32 {
                        for step in 0..5u64 {
                            let want = db.call_stack(app, rank, step);
                            let got = loaded.call_stack(app, rank, step);
                            if want.len() != got.len()
                                || got.iter().zip(want.iter()).any(|(g, w)| g != w)
                            {
                                return Err(format!("stack ({app},{rank},{step}) diverged"));
                            }
                        }
                    }
                }
                Ok(())
            })();
            std::fs::remove_dir_all(&dir).ok();
            result
        },
    );
}
