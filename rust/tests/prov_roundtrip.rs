//! Properties of the provenance serializations:
//!
//! 1. `ProvRecord` JSONL serialization and the `ProvDb::load` index are
//!    faithful — write N random records to disk, reload, and the store
//!    answers every query and call-stack request identically to the
//!    original in-memory index.
//! 2. The binary codec (`provenance::codec`) round-trips losslessly and
//!    agrees with the JSON codec record-for-record (including score edge
//!    values, empty call-stack fields, unicode function names and
//!    custom labels), and its header-only predicate evaluation never
//!    disagrees with `ProvQuery::matches`.

use chimbuko::provenance::{codec, ProvDb, ProvQuery, ProvRecord};
use chimbuko::util::prop::{check, Config as PropConfig};
use chimbuko::util::rng::Rng;
use std::path::PathBuf;

/// Random record; `entry_us`/`score` ranges are disjoint per `i` so that
/// global orderings are unambiguous across the per-(app,rank) files
/// `ProvDb::load` reads back in path order (within one file the relative
/// order is preserved; across files only the sort keys order records).
fn record(rng: &mut Rng, i: u64) -> ProvRecord {
    let entry = i * 1_000 + rng.range_u64(0, 999);
    let dur = rng.range_u64(1, 5_000);
    let score = i as f64 * 0.5 + rng.range_f64(0.0, 0.4);
    let label = ["normal", "anomaly_high", "anomaly_low"][rng.usize(3)];
    ProvRecord {
        call_id: i,
        app: rng.usize(2) as u32,
        rank: rng.usize(4) as u32,
        thread: rng.usize(2) as u32,
        fid: rng.usize(7) as u32,
        // Exercise the JSON escaping path too.
        func: format!("FN_{}_\"q\"\n", rng.usize(7)),
        step: rng.usize(5) as u64,
        entry_us: entry,
        exit_us: entry + dur,
        inclusive_us: dur,
        exclusive_us: rng.range_u64(0, dur),
        depth: rng.usize(4) as u32,
        parent: if rng.chance(0.4) { Some(rng.range_u64(0, 1 << 40)) } else { None },
        n_children: rng.usize(5) as u32,
        n_messages: rng.usize(5) as u32,
        msg_bytes: rng.range_u64(0, 1 << 20),
        label: label.to_string(),
        score,
    }
}

fn queries() -> Vec<ProvQuery> {
    let mut qs = vec![
        ProvQuery::default(),
        ProvQuery { anomalies_only: true, ..Default::default() },
        ProvQuery { order_by_score: true, limit: Some(9), ..Default::default() },
        ProvQuery { min_score: Some(3.0), order_by_score: true, ..Default::default() },
        ProvQuery { label: Some("anomaly_low".to_string()), ..Default::default() },
        ProvQuery { step_range: Some((1, 3)), ..Default::default() },
        ProvQuery { ts_range: Some((5_000, 40_000)), ..Default::default() },
    ];
    for app in 0..2u32 {
        for rank in 0..4u32 {
            qs.push(ProvQuery { rank: Some((app, rank)), ..Default::default() });
        }
        for fid in 0..7u32 {
            qs.push(ProvQuery { fid: Some((app, fid)), ..Default::default() });
        }
    }
    qs
}

fn tmpdir(tag: u64) -> PathBuf {
    std::env::temp_dir().join(format!(
        "chimbuko-prov-rt-{}-{tag}",
        std::process::id()
    ))
}

/// Edge-case-heavy random record for the codec property: unicode and
/// escape-needing function names, custom labels, NaN-free score edge
/// values (exact zeros, subnormals, huge magnitudes, negatives), empty
/// call-stack shape (no parent, depth 0, no children), and u64 fields
/// kept within the 2^53 range where the JSON number path is lossless.
fn codec_record(rng: &mut Rng, i: u64) -> ProvRecord {
    let funcs = ["MD_NEWTON", "λ_solver \"q\"\n", "汉字::kernel", "", "f\tg\\h"];
    let labels = ["normal", "anomaly_high", "anomaly_low", "custom_label", "très_étrange"];
    let scores = [0.0, -0.0, 1.5e-308, 9.25, -3.75, 1.0e15, 6.0, 0.125];
    let empty_stack = rng.chance(0.3);
    let entry = rng.range_u64(0, 1 << 50);
    ProvRecord {
        call_id: rng.range_u64(0, 1 << 53),
        app: rng.usize(3) as u32,
        rank: rng.usize(1 << 16) as u32,
        thread: rng.usize(4) as u32,
        fid: rng.usize(1 << 20) as u32,
        func: funcs[rng.usize(funcs.len())].to_string(),
        step: rng.range_u64(0, 1 << 40),
        entry_us: entry,
        exit_us: entry + rng.range_u64(0, 1 << 30),
        inclusive_us: rng.range_u64(0, 1 << 40),
        exclusive_us: rng.range_u64(0, 1 << 40),
        depth: if empty_stack { 0 } else { rng.usize(64) as u32 },
        parent: if empty_stack { None } else { Some(rng.range_u64(0, 1 << 53)) },
        n_children: if empty_stack { 0 } else { rng.usize(32) as u32 },
        n_messages: rng.usize(32) as u32,
        msg_bytes: rng.range_u64(0, 1 << 40),
        label: labels[rng.usize(labels.len())].to_string(),
        score: scores[(i as usize + rng.usize(scores.len())) % scores.len()],
    }
}

fn random_query(rng: &mut Rng) -> ProvQuery {
    let labels = ["normal", "anomaly_high", "anomaly_low", "custom_label", "nope"];
    ProvQuery {
        app: if rng.chance(0.3) { Some(rng.usize(3) as u32) } else { None },
        rank: if rng.chance(0.3) {
            Some((rng.usize(3) as u32, rng.usize(1 << 16) as u32))
        } else {
            None
        },
        fid: if rng.chance(0.3) {
            Some((rng.usize(3) as u32, rng.usize(1 << 20) as u32))
        } else {
            None
        },
        step: if rng.chance(0.3) { Some(rng.range_u64(0, 1 << 40)) } else { None },
        step_range: if rng.chance(0.3) {
            let lo = rng.range_u64(0, 1 << 40);
            Some((lo, lo + rng.range_u64(0, 1 << 39)))
        } else {
            None
        },
        ts_range: if rng.chance(0.3) {
            let lo = rng.range_u64(0, 1 << 50);
            Some((lo, lo + rng.range_u64(0, 1 << 30)))
        } else {
            None
        },
        anomalies_only: rng.chance(0.4),
        min_score: if rng.chance(0.4) { Some([-1.0, 0.0, 0.2, 6.0][rng.usize(4)]) } else { None },
        label: if rng.chance(0.4) {
            Some(labels[rng.usize(labels.len())].to_string())
        } else {
            None
        },
        order_by_score: rng.chance(0.3),
        limit: None,
    }
}

#[test]
fn prop_binary_codec_is_lossless_and_agrees_with_json() {
    check(
        "prov-binary-codec",
        PropConfig { cases: 30, seed: 0xB17C, max_size: 80 },
        |rng, size| {
            let n = (size as u64).max(8);
            let mut batch = Vec::new();
            let mut recs = Vec::new();
            for i in 0..n {
                let rec = codec_record(rng, i);
                // Binary round-trip is bit-lossless.
                let mut buf = Vec::new();
                codec::encode(&rec, &mut buf);
                let len = codec::validate(&buf).map_err(|e| e.to_string())?;
                if len != buf.len() {
                    return Err(format!("validate len {len} != {}", buf.len()));
                }
                let (back, used) = codec::decode(&buf).map_err(|e| e.to_string())?;
                if used != buf.len() || back != rec {
                    return Err(format!("binary round-trip diverged at record {i}"));
                }
                // JSON round-trip agrees with the binary one.
                let line = rec.to_json().to_string();
                let via_json =
                    ProvRecord::from_jsonl_line(&line).map_err(|e| e.to_string())?;
                if via_json != back {
                    return Err(format!("json vs binary diverged at record {i}"));
                }
                // Header carries the routing/filter fields faithfully.
                let h = codec::read_header(&buf).map_err(|e| e.to_string())?;
                if h.app != rec.app
                    || h.rank != rec.rank
                    || h.fid != rec.fid
                    || h.step != rec.step
                    || h.entry_us != rec.entry_us
                    || h.exit_us != rec.exit_us
                    || h.score.to_bits() != rec.score.to_bits()
                    || h.is_anomaly() != rec.is_anomaly()
                {
                    return Err(format!("header fields diverged at record {i}"));
                }
                codec::encode(&rec, &mut batch);
                recs.push(rec);
            }
            // Concatenated records stay self-delimiting.
            let mut pos = 0usize;
            for (i, want) in recs.iter().enumerate() {
                let (got, used) =
                    codec::decode(&batch[pos..]).map_err(|e| e.to_string())?;
                if &got != want {
                    return Err(format!("batch decode diverged at record {i}"));
                }
                pos += used;
            }
            if pos != batch.len() {
                return Err("batch decode left trailing bytes".to_string());
            }
            // Header-level predicates never disagree with matches().
            for _ in 0..64 {
                let q = random_query(rng);
                for rec in &recs {
                    let mut buf = Vec::new();
                    codec::encode(rec, &mut buf);
                    let h = codec::read_header(&buf).map_err(|e| e.to_string())?;
                    match codec::matches_header(&q, &h) {
                        Some(v) => {
                            if v != q.matches(rec) {
                                return Err(format!(
                                    "header predicate diverged: {q:?} on {rec:?}"
                                ));
                            }
                        }
                        None => {} // undecidable: caller decodes + matches()
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_provdb_reload_answers_queries_identically() {
    check(
        "provdb-reload-equivalence",
        PropConfig { cases: 12, seed: 0x90B0, max_size: 120 },
        |rng, size| {
            let n = (size as u64).max(4);
            let dir = tmpdir(rng.range_u64(0, u64::MAX / 2));
            std::fs::remove_dir_all(&dir).ok();
            let mut db = ProvDb::create(&dir).map_err(|e| e.to_string())?;
            for i in 0..n {
                db.append_record(record(rng, i)).map_err(|e| e.to_string())?;
            }
            db.flush().map_err(|e| e.to_string())?;

            let loaded = ProvDb::load(&dir).map_err(|e| e.to_string())?;
            let result = (|| -> Result<(), String> {
                if loaded.len() != db.len() {
                    return Err(format!("len {} vs {}", loaded.len(), db.len()));
                }
                if loaded.anomaly_count() != db.anomaly_count() {
                    return Err("anomaly count diverged".to_string());
                }
                if loaded.bytes_written() != db.bytes_written() {
                    return Err("byte accounting diverged".to_string());
                }
                for q in queries() {
                    let want = db.query(&q);
                    let got = loaded.query(&q);
                    if want.len() != got.len() {
                        return Err(format!(
                            "query {q:?}: {} vs {} results",
                            got.len(),
                            want.len()
                        ));
                    }
                    for (g, w) in got.iter().zip(want.iter()) {
                        if g != w {
                            return Err(format!("query {q:?} diverged at call {}", w.call_id));
                        }
                    }
                }
                for app in 0..2u32 {
                    for rank in 0..4u32 {
                        for step in 0..5u64 {
                            let want = db.call_stack(app, rank, step);
                            let got = loaded.call_stack(app, rank, step);
                            if want.len() != got.len()
                                || got.iter().zip(want.iter()).any(|(g, w)| g != w)
                            {
                                return Err(format!("stack ({app},{rank},{step}) diverged"));
                            }
                        }
                    }
                }
                Ok(())
            })();
            std::fs::remove_dir_all(&dir).ok();
            result
        },
    );
}
