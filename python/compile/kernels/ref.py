"""Pure-jnp oracle for the L1 kernels.

Everything here is straight-line numpy-style code — no pallas — and is the
single source of truth the kernels and the Rust detector are tested
against. Semantics (matching ``rust/src/ad/detector.rs``):

1. merge the batch's per-function statistics into the running
   ``(n, mean, M2)`` via Pébay's pairwise formulas;
2. label every event against the *merged* statistics with the paper's
   ``mu ± alpha * sigma`` thresholds (sample std-dev, ``n-1``);
3. warm-up: a function with fewer than ``min_samples`` merged observations
   (or zero variance) is never anomalous.

Labels: 0 = normal, 1 = anomaly-high, -1 = anomaly-low.
"""

import jax.numpy as jnp


def segment_stats_ref(exec_us, fid, valid, mu_old, num_funcs):
    """Per-function batch statistics, shifted by the running mean.

    Returns ``(cnt[F], s1[F], s2[F])`` where, per function f over the valid
    events with ``fid == f``::

        cnt = #events
        s1  = sum(x - mu_old[f])
        s2  = sum((x - mu_old[f])**2)

    The shift keeps the sums small relative to the raw magnitudes, which is
    what makes the f32 matmul path numerically safe (see DESIGN.md §4).
    """
    onehot = (fid[:, None] == jnp.arange(num_funcs, dtype=fid.dtype)[None, :]).astype(
        exec_us.dtype
    ) * valid[:, None]
    mu_g = onehot @ mu_old  # per-event gather of the running mean
    d = (exec_us - mu_g) * valid
    cnt = valid @ onehot
    s1 = d @ onehot
    s2 = (d * d) @ onehot
    return cnt, s1, s2


def pebay_merge_ref(n_old, mu_old, m2_old, cnt, s1, s2):
    """Merge shifted batch sums into running stats (Pébay pairwise).

    Batch stats recovered from the shifted sums:
        mean_b = mu_old + s1 / cnt
        M2_b   = s2 - s1**2 / cnt
    """
    safe_cnt = jnp.maximum(cnt, 1.0)
    mean_b = mu_old + s1 / safe_cnt
    m2_b = jnp.maximum(s2 - (s1 * s1) / safe_cnt, 0.0)

    n_new = n_old + cnt
    safe_n = jnp.maximum(n_new, 1.0)
    delta = mean_b - mu_old
    mu_new = jnp.where(cnt > 0, mu_old + delta * cnt / safe_n, mu_old)
    m2_new = jnp.where(
        cnt > 0, m2_old + m2_b + delta * delta * n_old * cnt / safe_n, m2_old
    )
    return n_new, mu_new, m2_new


def thresholds_ref(n, mu, m2, alpha, min_samples):
    """Per-function ``(lo, hi, sd, eligible)`` from merged stats."""
    sd = jnp.sqrt(m2 / jnp.maximum(n - 1.0, 1.0))
    eligible = (n >= min_samples) & (sd > 0.0)
    lo = mu - alpha * sd
    hi = mu + alpha * sd
    return lo, hi, sd, eligible


def label_ref(exec_us, fid, valid, lo, hi, mu, sd, eligible, num_funcs):
    """Label events against per-function thresholds.

    Returns ``(labels[B] int32, scores[B] f32)``; scores are sigma-distance
    ``|x - mu| / sd`` (0 where sd == 0 or the event is invalid/ineligible).
    """
    onehot = (fid[:, None] == jnp.arange(num_funcs, dtype=fid.dtype)[None, :]).astype(
        exec_us.dtype
    ) * valid[:, None]
    lo_g = onehot @ lo
    hi_g = onehot @ hi
    mu_g = onehot @ mu
    sd_g = onehot @ sd
    el_g = (onehot @ eligible.astype(exec_us.dtype)) > 0.5
    ok = (valid > 0.5) & el_g
    score = jnp.where(
        ok & (sd_g > 0), jnp.abs(exec_us - mu_g) / jnp.maximum(sd_g, 1e-30), 0.0
    )
    high = ok & (exec_us > hi_g)
    low = ok & (exec_us < lo_g)
    labels = jnp.where(high, 1, jnp.where(low, -1, 0)).astype(jnp.int32)
    return labels, score


def ad_batch_ref(exec_us, fid, valid, n_old, mu_old, m2_old, alpha, min_samples):
    """Full reference pipeline: stats -> merge -> thresholds -> labels."""
    num_funcs = mu_old.shape[0]
    cnt, s1, s2 = segment_stats_ref(exec_us, fid, valid, mu_old, num_funcs)
    n_new, mu_new, m2_new = pebay_merge_ref(n_old, mu_old, m2_old, cnt, s1, s2)
    lo, hi, sd, eligible = thresholds_ref(n_new, mu_new, m2_new, alpha, min_samples)
    labels, scores = label_ref(
        exec_us, fid, valid, lo, hi, mu_new, sd, eligible, num_funcs
    )
    return labels, scores, n_new, mu_new, m2_new


def ps_merge_ref(n_a, mu_a, m2_a, n_b, mu_b, m2_b):
    """Elementwise Pébay merge of two stats tables (parameter server)."""
    n = n_a + n_b
    safe_n = jnp.maximum(n, 1.0)
    delta = mu_b - mu_a
    both = (n_a > 0) & (n_b > 0)
    mu = jnp.where(both, mu_a + delta * n_b / safe_n, jnp.where(n_a > 0, mu_a, mu_b))
    m2 = jnp.where(both, m2_a + m2_b + delta * delta * n_a * n_b / safe_n, m2_a + m2_b)
    return n, mu, m2
