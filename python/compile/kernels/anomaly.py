"""L1 Pallas kernels: segment statistics + anomaly labelling.

The hot spot of Chimbuko's on-node AD is per-function streaming statistics
over event batches — on GPUs this is a scatter-add; on TPU we recast it as
**one-hot matmuls on the MXU** (DESIGN.md §4):

    onehot[B, F] = (fid[b] == iota[F]) & valid[b]
    packed[3, B] = stack(valid, d, d*d)        # d = x - mu_old[fid]
    sums[3, F]   = packed @ onehot             # one MXU matmul, M=3 packing

Shifting by the running mean ``mu_old`` keeps the summands small, so the
f32 matmul path is numerically stable even for microsecond timestamps in
the 1e6+ range (classic sum-of-squares cancellation is avoided).

Both kernels tile the batch dimension with a grid; the [3, F] accumulator
(and the [B_t, F] onehot tile) live in VMEM. ``interpret=True`` everywhere:
the CPU PJRT plugin cannot execute Mosaic custom-calls; on a real TPU the
same BlockSpecs compile natively (VMEM estimate in DESIGN.md).

Label codes match ``ref.py``: 0 normal, 1 high, -1 low.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch-tile size for the grid walk. 128 rows x F columns of f32 onehot is
# 32 KiB at F=64 — comfortably inside VMEM next to the [3, F] accumulator.
BLOCK_B = 128


def _segment_stats_kernel(exec_ref, fid_ref, valid_ref, mu_ref, out_ref):
    """One grid step: accumulate [3, F] shifted sums for a batch tile."""
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = exec_ref[...]  # [Bt]
    fid = fid_ref[...]  # [Bt] int32
    valid = valid_ref[...]  # [Bt] f32
    num_funcs = mu_ref.shape[0]

    onehot = (
        fid[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, num_funcs), 1)
    ).astype(x.dtype) * valid[:, None]  # [Bt, F]

    mu_g = onehot @ mu_ref[...]  # [Bt] gather of running means
    d = (x - mu_g) * valid
    packed = jnp.stack([valid, d, d * d])  # [3, Bt]
    out_ref[...] += packed @ onehot  # [3, F] on the MXU


def segment_stats(exec_us, fid, valid, mu_old, *, block_b: int = BLOCK_B):
    """Pallas segment statistics: returns ``(cnt[F], s1[F], s2[F])``.

    ``B`` must be a multiple of ``block_b`` (the coordinator pads batches).
    """
    batch, = exec_us.shape
    num_funcs, = mu_old.shape
    assert batch % block_b == 0, f"batch {batch} not a multiple of {block_b}"
    grid = (batch // block_b,)
    out = pl.pallas_call(
        _segment_stats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((num_funcs,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((3, num_funcs), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((3, num_funcs), exec_us.dtype),
        interpret=True,
    )(exec_us, fid, valid, mu_old)
    return out[0], out[1], out[2]


def _label_kernel(exec_ref, fid_ref, valid_ref, thr_ref, labels_ref, scores_ref):
    """One grid step: label a batch tile against per-function thresholds.

    ``thr_ref`` packs [4, F]: lo, hi, mu, sd_eff where sd_eff = sd when the
    function is eligible else 0 (ineligible functions never label).
    """
    x = exec_ref[...]
    fid = fid_ref[...]
    valid = valid_ref[...]
    num_funcs = thr_ref.shape[1]

    onehot = (
        fid[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, num_funcs), 1)
    ).astype(x.dtype) * valid[:, None]

    gathered = onehot @ thr_ref[...].T  # [Bt, 4] — one MXU matmul
    lo_g = gathered[:, 0]
    hi_g = gathered[:, 1]
    mu_g = gathered[:, 2]
    sd_g = gathered[:, 3]

    ok = (valid > 0.5) & (sd_g > 0.0)
    scores_ref[...] = jnp.where(
        ok, jnp.abs(x - mu_g) / jnp.maximum(sd_g, 1e-30), 0.0
    )
    high = ok & (x > hi_g)
    low = ok & (x < lo_g)
    labels_ref[...] = jnp.where(high, 1, jnp.where(low, -1, 0)).astype(jnp.int32)


def label(exec_us, fid, valid, lo, hi, mu, sd_eff, *, block_b: int = BLOCK_B):
    """Pallas labelling: ``(labels[B] int32, scores[B] f32)``.

    ``sd_eff`` must already be zeroed for ineligible functions (warm-up /
    zero variance) — done by the L2 graph from the merged stats.
    """
    batch, = exec_us.shape
    num_funcs, = lo.shape
    assert batch % block_b == 0, f"batch {batch} not a multiple of {block_b}"
    thr = jnp.stack([lo, hi, mu, sd_eff])  # [4, F]
    grid = (batch // block_b,)
    return pl.pallas_call(
        _label_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((4, num_funcs), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b,), lambda i: (i,)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch,), exec_us.dtype),
        ],
        interpret=True,
    )(exec_us, fid, valid, thr)


@functools.partial(jax.jit, static_argnames=("num_funcs",))
def segment_stats_jit(exec_us, fid, valid, mu_old, num_funcs: int):
    """Jitted wrapper (tests)."""
    del num_funcs
    return segment_stats(exec_us, fid, valid, mu_old)
