"""L2 compute graphs (build-time JAX, AOT-lowered to HLO text).

Two graphs, both loaded and executed from the Rust coordinator via PJRT:

* ``ad_batch`` — the on-node AD hot path: one padded event batch
  ``(exec[B], fid[B], valid[B])`` plus running per-function stats
  ``(n[F], mu[F], m2[F])`` and scalars ``(alpha, min_samples)`` →
  ``(labels[B], scores[B], n'[F], mu'[F], m2'[F])``. Segment statistics
  and labelling run in the L1 Pallas kernels; the Pébay merge and the
  threshold computation are fused jnp between them.

* ``ps_merge`` — elementwise Pébay merge of two stats tables (the
  parameter server folds rank deltas with it).

Shapes are baked at AOT time (defaults ``B=256, F=64``); scalars stay
runtime inputs so α and the warm-up count are configurable without
re-compiling artifacts.
"""

import jax.numpy as jnp

from .kernels import anomaly
from .kernels.ref import thresholds_ref


def ad_batch(exec_us, fid, valid, n_old, mu_old, m2_old, alpha, min_samples):
    """On-node AD step. See module docstring; semantics match
    ``kernels.ref.ad_batch_ref`` exactly (tested)."""
    # L1 kernel: per-function shifted batch sums on the MXU.
    cnt, s1, s2 = anomaly.segment_stats(exec_us, fid, valid, mu_old)

    # Pébay merge of the batch into the running stats (O(F) elementwise,
    # fused by XLA around the kernel calls).
    safe_cnt = jnp.maximum(cnt, 1.0)
    mean_b = mu_old + s1 / safe_cnt
    m2_b = jnp.maximum(s2 - (s1 * s1) / safe_cnt, 0.0)
    n_new = n_old + cnt
    safe_n = jnp.maximum(n_new, 1.0)
    delta = mean_b - mu_old
    mu_new = jnp.where(cnt > 0, mu_old + delta * cnt / safe_n, mu_old)
    m2_new = jnp.where(
        cnt > 0, m2_old + m2_b + delta * delta * n_old * cnt / safe_n, m2_old
    )

    # Thresholds with warm-up gating baked into sd_eff.
    lo, hi, sd, eligible = thresholds_ref(n_new, mu_new, m2_new, alpha, min_samples)
    sd_eff = jnp.where(eligible, sd, 0.0)

    # L1 kernel: threshold lookup + labels, reusing the onehot tiling.
    labels, scores = anomaly.label(exec_us, fid, valid, lo, hi, mu_new, sd_eff)
    return labels, scores, n_new, mu_new, m2_new


def ps_merge(n_a, mu_a, m2_a, n_b, mu_b, m2_b):
    """Parameter-server pairwise merge (a ⊕ b), elementwise over [F]."""
    n = n_a + n_b
    safe_n = jnp.maximum(n, 1.0)
    delta = mu_b - mu_a
    both = (n_a > 0) & (n_b > 0)
    mu = jnp.where(both, mu_a + delta * n_b / safe_n, jnp.where(n_a > 0, mu_a, mu_b))
    m2 = jnp.where(both, m2_a + m2_b + delta * delta * n_a * n_b / safe_n, m2_a + m2_b)
    return n, mu, m2
