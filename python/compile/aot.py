"""AOT emitter: lower the L2 graphs to HLO **text** artifacts.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (under ``--out-dir``):

* ``ad_batch.hlo.txt``  — 8 inputs, 5-tuple output (see model.ad_batch)
* ``ps_merge.hlo.txt``  — 6 inputs, 3-tuple output
* ``manifest.json``     — baked shapes + input/output orders, read by
  ``rust/src/runtime`` at load time so shape drift fails loudly.

Run: ``cd python && python -m compile.aot --out-dir ../artifacts``
(idempotent; `make artifacts` wires it up).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Baked shapes. B must be a multiple of kernels.anomaly.BLOCK_B.
DEFAULT_BATCH = 256
DEFAULT_FUNCS = 64


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_ad_batch(batch: int, funcs: int) -> str:
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct
    lowered = jax.jit(model.ad_batch).lower(
        spec((batch,), f32),  # exec_us
        spec((batch,), i32),  # fid
        spec((batch,), f32),  # valid
        spec((funcs,), f32),  # n_old
        spec((funcs,), f32),  # mu_old
        spec((funcs,), f32),  # m2_old
        spec((), f32),        # alpha
        spec((), f32),        # min_samples
    )
    return to_hlo_text(lowered)


def lower_ps_merge(funcs: int) -> str:
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    args = [spec((funcs,), f32)] * 6
    lowered = jax.jit(model.ps_merge).lower(*args)
    return to_hlo_text(lowered)


def manifest(batch: int, funcs: int) -> dict:
    return {
        "version": 1,
        "batch": batch,
        "funcs": funcs,
        "ad_batch": {
            "file": "ad_batch.hlo.txt",
            "inputs": [
                "exec_us[B]f32",
                "fid[B]i32",
                "valid[B]f32",
                "n_old[F]f32",
                "mu_old[F]f32",
                "m2_old[F]f32",
                "alpha[]f32",
                "min_samples[]f32",
            ],
            "outputs": ["labels[B]i32", "scores[B]f32", "n[F]f32", "mu[F]f32", "m2[F]f32"],
        },
        "ps_merge": {
            "file": "ps_merge.hlo.txt",
            "inputs": ["n_a[F]f32", "mu_a[F]f32", "m2_a[F]f32", "n_b[F]f32", "mu_b[F]f32", "m2_b[F]f32"],
            "outputs": ["n[F]f32", "mu[F]f32", "m2[F]f32"],
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--funcs", type=int, default=DEFAULT_FUNCS)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)

    ad_text = lower_ad_batch(args.batch, args.funcs)
    with open(os.path.join(args.out_dir, "ad_batch.hlo.txt"), "w") as f:
        f.write(ad_text)
    print(f"ad_batch.hlo.txt: {len(ad_text)} chars (B={args.batch}, F={args.funcs})")

    ps_text = lower_ps_merge(args.funcs)
    with open(os.path.join(args.out_dir, "ps_merge.hlo.txt"), "w") as f:
        f.write(ps_text)
    print(f"ps_merge.hlo.txt: {len(ps_text)} chars (F={args.funcs})")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest(args.batch, args.funcs), f, indent=2)
    print("manifest.json written")


if __name__ == "__main__":
    main()
