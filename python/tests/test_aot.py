"""AOT artifact tests: HLO text is well-formed, parameter/tuple shapes
match the manifest, and the lowered module re-executes (via jax) with the
same numerics as the eager graph — i.e. what the Rust runtime will see."""

import json
import os

import numpy as np
import jax.numpy as jnp

from compile import aot, model


def test_ad_batch_hlo_text_shape_signature():
    text = aot.lower_ad_batch(256, 64)
    assert text.startswith("HloModule")
    # Entry layout: 8 params, 5-tuple result.
    assert "f32[256]" in text and "s32[256]" in text and "f32[64]" in text
    assert "->(s32[256]{0}, f32[256]{0}, f32[64]{0}, f32[64]{0}, f32[64]{0})" in text


def test_ps_merge_hlo_text_shape_signature():
    text = aot.lower_ps_merge(64)
    assert text.startswith("HloModule")
    assert text.count("f32[64]") >= 9  # 6 inputs + 3 outputs


def test_alternate_shapes_lower():
    text = aot.lower_ad_batch(128, 16)
    assert "f32[128]" in text and "f32[16]" in text


def test_manifest_structure():
    m = aot.manifest(256, 64)
    assert m["batch"] == 256 and m["funcs"] == 64
    assert len(m["ad_batch"]["inputs"]) == 8
    assert len(m["ad_batch"]["outputs"]) == 5
    assert len(m["ps_merge"]["inputs"]) == 6
    json.dumps(m)  # serializable


def test_artifacts_on_disk_match_current_lowering(tmp_path):
    # Emit into a temp dir exactly as `make artifacts` does.
    import sys

    argv = sys.argv
    sys.argv = ["aot", "--out-dir", str(tmp_path), "--batch", "256", "--funcs", "64"]
    try:
        aot.main()
    finally:
        sys.argv = argv
    for name in ("ad_batch.hlo.txt", "ps_merge.hlo.txt", "manifest.json"):
        assert (tmp_path / name).exists(), name
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["batch"] == 256
    text = (tmp_path / "ad_batch.hlo.txt").read_text()
    assert text.startswith("HloModule")


def test_checked_in_artifacts_if_present():
    """If `make artifacts` has run, the files must match current shapes."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        return
    manifest = json.loads(open(manifest_path).read())
    text = open(os.path.join(art, manifest["ad_batch"]["file"])).read()
    assert f"f32[{manifest['batch']}]" in text
    assert f"f32[{manifest['funcs']}]" in text


def test_eager_equals_jit_numerics():
    rng = np.random.default_rng(0)
    B, F = 256, 64
    args = (
        jnp.array(rng.lognormal(6, 1, B).astype(np.float32)),
        jnp.array(rng.integers(0, F, B).astype(np.int32)),
        jnp.array((rng.random(B) < 0.8).astype(np.float32)),
        jnp.array(rng.integers(0, 50, F).astype(np.float32)),
        jnp.array(rng.lognormal(6, 1, F).astype(np.float32)),
        jnp.array((rng.random(F) * 100).astype(np.float32)),
        jnp.float32(6.0),
        jnp.float32(10.0),
    )
    import jax

    eager = model.ad_batch(*args)
    jitted = jax.jit(model.ad_batch)(*args)
    for e, j in zip(eager, jitted):
        np.testing.assert_allclose(np.asarray(e), np.asarray(j), rtol=1e-5, atol=1e-5)
