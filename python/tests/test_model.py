"""L2 graph tests: ps_merge semantics + ad_batch behavioural contracts."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import ps_merge_ref


def stats_of(values):
    values = np.asarray(values, dtype=np.float64)
    n = float(len(values))
    if n == 0:
        return 0.0, 0.0, 0.0
    mean = values.mean()
    m2 = ((values - mean) ** 2).sum()
    return n, mean, m2


class TestPsMerge:
    @settings(max_examples=50, deadline=None)
    @given(
        na=st.integers(0, 50),
        nb=st.integers(0, 50),
        loc=st.sampled_from([5.0, 1e3, 1e6]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_merge_equals_concat(self, na, nb, loc, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(loc, loc * 0.05, na)
        b = rng.normal(loc * 1.1, loc * 0.02, nb)
        sa, sb = stats_of(a), stats_of(b)
        sw = stats_of(np.concatenate([a, b]))
        funcs = 4
        mk = lambda s: tuple(jnp.full(funcs, np.float32(x)) for x in s)
        n, mu, m2 = model.ps_merge(*mk(sa), *mk(sb))
        assert abs(float(n[0]) - sw[0]) < 1e-3
        if sw[0] > 0:
            np.testing.assert_allclose(float(mu[0]), sw[1], rtol=1e-4)
            np.testing.assert_allclose(float(m2[0]), sw[2], rtol=2e-3, atol=1e-2)

    def test_empty_sides(self):
        funcs = 3
        z = jnp.zeros(funcs)
        st_b = (jnp.full(funcs, 5.0), jnp.full(funcs, 100.0), jnp.full(funcs, 80.0))
        n, mu, m2 = model.ps_merge(z, z, z, *st_b)
        np.testing.assert_allclose(np.asarray(n), 5.0)
        np.testing.assert_allclose(np.asarray(mu), 100.0)
        np.testing.assert_allclose(np.asarray(m2), 80.0)
        # Symmetric case.
        n, mu, m2 = model.ps_merge(*st_b, z, z, z)
        np.testing.assert_allclose(np.asarray(mu), 100.0)

    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        funcs = 64
        args = [jnp.array(rng.random(funcs).astype(np.float32) * s) for s in (10, 1e3, 1e4, 10, 1e3, 1e4)]
        got = model.ps_merge(*args)
        want = ps_merge_ref(*args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)

    def test_commutative(self):
        rng = np.random.default_rng(4)
        funcs = 16
        a = [jnp.array((rng.random(funcs) * s).astype(np.float32)) for s in (20, 500, 1e3)]
        b = [jnp.array((rng.random(funcs) * s).astype(np.float32)) for s in (30, 700, 2e3)]
        ab = model.ps_merge(*a, *b)
        ba = model.ps_merge(*b, *a)
        for x, y in zip(ab, ba):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-5)


class TestAdBatchContracts:
    def test_six_sigma_on_clean_data_flags_nothing(self):
        rng = np.random.default_rng(11)
        funcs = 8
        n = jnp.full(funcs, 1000.0)
        mu = jnp.full(funcs, 2000.0)
        m2 = jnp.full(funcs, 1000.0 * 25.0**2)
        ex = jnp.array(rng.normal(2000.0, 25.0, 256).astype(np.float32))
        fid = jnp.array(rng.integers(0, funcs, 256).astype(np.int32))
        valid = jnp.ones(256, dtype=jnp.float32)
        labels, *_ = model.ad_batch(ex, fid, valid, n, mu, m2, 6.0, 10.0)
        assert int(np.abs(np.asarray(labels)).sum()) == 0

    def test_injected_outlier_is_flagged(self):
        funcs = 8
        n = jnp.full(funcs, 1000.0)
        mu = jnp.full(funcs, 2000.0)
        m2 = jnp.full(funcs, 1000.0 * 25.0**2)
        ex = np.full(256, 2000.0, dtype=np.float32)
        ex[17] = 50_000.0
        fid = np.zeros(256, dtype=np.int32)
        valid = np.ones(256, dtype=np.float32)
        labels, scores, *_ = model.ad_batch(
            jnp.array(ex), jnp.array(fid), jnp.array(valid), n, mu, m2, 6.0, 10.0
        )
        lab = np.asarray(labels)
        assert lab[17] == 1
        assert lab.sum() == 1
        assert float(np.asarray(scores)[17]) > 6.0

    def test_warmup_gates_labels(self):
        funcs = 4
        n = jnp.zeros(funcs)
        mu = jnp.zeros(funcs)
        m2 = jnp.zeros(funcs)
        rng = np.random.default_rng(5)
        ex = jnp.array(rng.normal(100.0, 5.0, 128).astype(np.float32))
        fid = jnp.zeros(128, dtype=jnp.int32)
        valid = jnp.ones(128, dtype=jnp.float32)
        # min_samples larger than the batch: nothing can be labelled.
        labels, *_ = model.ad_batch(ex, fid, valid, n, mu, m2, 6.0, 1000.0)
        assert int(np.abs(np.asarray(labels)).sum()) == 0

    def test_alpha_monotonicity(self):
        # Lower alpha can only flag more (or equal) events.
        rng = np.random.default_rng(6)
        funcs = 8
        n = jnp.full(funcs, 500.0)
        mu = jnp.full(funcs, 1000.0)
        m2 = jnp.full(funcs, 500.0 * 30.0**2)
        ex = jnp.array(rng.normal(1000.0, 90.0, 256).astype(np.float32))
        fid = jnp.array(rng.integers(0, funcs, 256).astype(np.int32))
        valid = jnp.ones(256, dtype=jnp.float32)
        counts = []
        for alpha in (2.0, 4.0, 8.0):
            labels, *_ = model.ad_batch(ex, fid, valid, n, mu, m2, alpha, 10.0)
            counts.append(int(np.abs(np.asarray(labels)).sum()))
        assert counts[0] >= counts[1] >= counts[2]
        assert counts[0] > 0  # 2 sigma on sigma-3x data must flag something
