"""L1 kernel correctness: Pallas vs the pure-jnp oracle.

Hypothesis sweeps shapes, dt-scales and occupancy patterns; every case
asserts allclose between ``kernels.anomaly`` and ``kernels.ref``. This is
the CORE correctness signal for the AOT artifact the Rust hot path runs.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import anomaly
from compile.kernels.ref import (
    ad_batch_ref,
    label_ref,
    segment_stats_ref,
    thresholds_ref,
)


def make_batch(rng, batch, funcs, scale=1e3, occupancy=0.9, active_funcs=None):
    active = active_funcs or funcs
    ex = rng.lognormal(np.log(scale), 0.5, batch).astype(np.float32)
    fid = rng.integers(0, active, batch).astype(np.int32)
    valid = (rng.random(batch) < occupancy).astype(np.float32)
    return jnp.array(ex), jnp.array(fid), jnp.array(valid)


def make_stats(rng, funcs, scale=1e3, warm=True):
    n = (rng.integers(20, 200, funcs) if warm else rng.integers(0, 3, funcs)).astype(
        np.float32
    )
    mu = rng.lognormal(np.log(scale), 0.5, funcs).astype(np.float32)
    m2 = (n * (0.05 * mu) ** 2).astype(np.float32)
    return jnp.array(n), jnp.array(mu), jnp.array(m2)


class TestSegmentStats:
    @pytest.mark.parametrize("batch", [128, 256, 512, 1024])
    @pytest.mark.parametrize("funcs", [8, 64])
    def test_matches_ref_across_shapes(self, batch, funcs):
        rng = np.random.default_rng(batch * 1000 + funcs)
        ex, fid, valid = make_batch(rng, batch, funcs)
        _, mu, _ = make_stats(rng, funcs)
        got = anomaly.segment_stats(ex, fid, valid, mu)
        want = segment_stats_ref(ex, fid, valid, mu, funcs)
        for g, w, name in zip(got, want, ["cnt", "s1", "s2"]):
            np.testing.assert_allclose(
                np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-4, err_msg=name
            )

    def test_counts_are_exact_integers(self):
        rng = np.random.default_rng(7)
        ex, fid, valid = make_batch(rng, 256, 16)
        _, mu, _ = make_stats(rng, 16)
        cnt, _, _ = anomaly.segment_stats(ex, fid, valid, mu)
        manual = np.zeros(16, dtype=np.float32)
        for f, v in zip(np.asarray(fid), np.asarray(valid)):
            manual[f] += v
        np.testing.assert_array_equal(np.asarray(cnt), manual)

    def test_all_invalid_gives_zeros(self):
        rng = np.random.default_rng(8)
        ex, fid, _ = make_batch(rng, 128, 8)
        valid = jnp.zeros(128, dtype=jnp.float32)
        _, mu, _ = make_stats(rng, 8)
        cnt, s1, s2 = anomaly.segment_stats(ex, fid, valid, mu)
        assert float(jnp.abs(cnt).sum()) == 0.0
        assert float(jnp.abs(s1).sum()) == 0.0
        assert float(jnp.abs(s2).sum()) == 0.0

    def test_single_function_concentration(self):
        # All events on one fid: s1/s2 match direct computation.
        rng = np.random.default_rng(9)
        batch, funcs = 256, 32
        ex = rng.normal(5000.0, 40.0, batch).astype(np.float32)
        fid = np.full(batch, 13, dtype=np.int32)
        valid = np.ones(batch, dtype=np.float32)
        mu = np.full(funcs, 5000.0, dtype=np.float32)
        cnt, s1, s2 = anomaly.segment_stats(
            jnp.array(ex), jnp.array(fid), jnp.array(valid), jnp.array(mu)
        )
        assert float(cnt[13]) == batch
        d = ex - 5000.0
        np.testing.assert_allclose(float(s1[13]), d.sum(), rtol=1e-4)
        np.testing.assert_allclose(float(s2[13]), (d * d).sum(), rtol=1e-4)

    def test_large_magnitude_stability(self):
        # Values near 1e6 with sigma 100: naive f32 sum-of-squares loses the
        # variance entirely; the mean-shifted kernel keeps ~1e-3 accuracy.
        rng = np.random.default_rng(10)
        batch, funcs = 512, 8
        ex = rng.normal(1.0e6, 100.0, batch).astype(np.float32)
        fid = rng.integers(0, funcs, batch).astype(np.int32)
        valid = np.ones(batch, dtype=np.float32)
        mu = np.full(funcs, 1.0e6, dtype=np.float32)
        cnt, s1, s2 = anomaly.segment_stats(
            jnp.array(ex), jnp.array(fid), jnp.array(valid), jnp.array(mu)
        )
        # Recovered per-function variance should be ~100^2.
        c = np.asarray(cnt)
        var = (np.asarray(s2) - np.asarray(s1) ** 2 / np.maximum(c, 1)) / np.maximum(
            c - 1, 1
        )
        assert np.all(var[c > 10] > 100.0**2 * 0.5)
        assert np.all(var[c > 10] < 100.0**2 * 2.0)


class TestLabel:
    def test_matches_ref(self):
        rng = np.random.default_rng(21)
        batch, funcs = 256, 64
        ex, fid, valid = make_batch(rng, batch, funcs)
        n, mu, m2 = make_stats(rng, funcs)
        lo, hi, sd, eligible = thresholds_ref(n, mu, m2, 6.0, 10.0)
        sd_eff = jnp.where(eligible, sd, 0.0)
        labels, scores = anomaly.label(ex, fid, valid, lo, hi, mu, sd_eff)
        want_labels, want_scores = label_ref(
            ex, fid, valid, lo, hi, mu, sd, eligible, funcs
        )
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(want_labels))
        np.testing.assert_allclose(
            np.asarray(scores), np.asarray(want_scores), rtol=1e-5, atol=1e-5
        )

    def test_extremes_label_high_and_low(self):
        funcs = 8
        n = jnp.full(funcs, 100.0)
        mu = jnp.full(funcs, 1000.0)
        m2 = jnp.full(funcs, 100.0 * 10.0**2)  # sd ~ 10
        lo, hi, sd, eligible = thresholds_ref(n, mu, m2, 6.0, 10.0)
        sd_eff = jnp.where(eligible, sd, 0.0)
        ex = jnp.array([1000.0, 2000.0, 10.0, 1030.0] * 32, dtype=jnp.float32)
        fid = jnp.zeros(128, dtype=jnp.int32)
        valid = jnp.ones(128, dtype=jnp.float32)
        labels, scores = anomaly.label(ex, fid, valid, lo, hi, mu, sd_eff)
        lab = np.asarray(labels).reshape(-1, 4)
        assert (lab[:, 0] == 0).all()
        assert (lab[:, 1] == 1).all()
        assert (lab[:, 2] == -1).all()
        assert (lab[:, 3] == 0).all()  # 3 sigma < 6 sigma threshold
        sc = np.asarray(scores).reshape(-1, 4)
        assert np.allclose(sc[:, 0], 0.0, atol=1e-5)
        assert (sc[:, 1] > 6.0).all()


class TestAdBatchPipeline:
    @settings(max_examples=30, deadline=None)
    @given(
        batch_blocks=st.integers(1, 4),
        funcs=st.sampled_from([8, 16, 64, 128]),
        scale=st.sampled_from([10.0, 1e3, 1e6]),
        occupancy=st.floats(0.0, 1.0),
        warm=st.booleans(),
        alpha=st.sampled_from([3.0, 6.0, 12.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pipeline_matches_ref_hypothesis(
        self, batch_blocks, funcs, scale, occupancy, warm, alpha, seed
    ):
        batch = batch_blocks * anomaly.BLOCK_B
        rng = np.random.default_rng(seed)
        ex, fid, valid = make_batch(
            rng, batch, funcs, scale=scale, occupancy=occupancy
        )
        n, mu, m2 = make_stats(rng, funcs, scale=scale, warm=warm)

        def pipeline(ex, fid, valid, n, mu, m2):
            from compile import model

            return model.ad_batch(ex, fid, valid, n, mu, m2, alpha, 10.0)

        got = pipeline(ex, fid, valid, n, mu, m2)
        want = ad_batch_ref(ex, fid, valid, n, mu, m2, alpha, 10.0)
        np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
        for i in (1, 2, 3):
            np.testing.assert_allclose(
                np.asarray(got[i]),
                np.asarray(want[i]),
                rtol=2e-5,
                atol=2e-4,
                err_msg=f"output {i}",
            )
        # M2 accumulates across grid blocks in a different order than the
        # single-shot oracle — allow a slightly wider f32 tolerance.
        np.testing.assert_allclose(
            np.asarray(got[4]), np.asarray(want[4]), rtol=2e-4, atol=1e-3,
            err_msg="output 4 (m2)",
        )

    def test_repeated_batches_converge_to_stream_stats(self):
        # Feeding k batches through ad_batch equals one big Welford stream.
        rng = np.random.default_rng(33)
        funcs = 16
        n = jnp.zeros(funcs)
        mu = jnp.zeros(funcs)
        m2 = jnp.zeros(funcs)
        all_values = {f: [] for f in range(funcs)}
        from compile import model

        for _ in range(5):
            ex, fid, valid = make_batch(rng, 256, funcs, scale=500.0)
            for x, f, v in zip(np.asarray(ex), np.asarray(fid), np.asarray(valid)):
                if v > 0.5:
                    all_values[int(f)].append(float(x))
            _, _, n, mu, m2 = model.ad_batch(ex, fid, valid, n, mu, m2, 6.0, 10.0)
        for f in range(funcs):
            vals = np.array(all_values[f])
            if len(vals) < 2:
                continue
            assert abs(float(n[f]) - len(vals)) < 1e-3
            np.testing.assert_allclose(float(mu[f]), vals.mean(), rtol=1e-4)
            np.testing.assert_allclose(
                float(m2[f]) / (len(vals) - 1), vals.var(ddof=1), rtol=1e-2
            )
